//! PJRT runtime: load the AOT artifacts and execute them from rust.
//!
//! The python compile path (`make artifacts`) leaves shape-specialized
//! HLO-text files plus `manifest.json` in `artifacts/`; this module is the
//! only place that touches PJRT.  [`Engine`] owns one CPU client, compiles
//! each artifact on first use, validates every call against the manifest
//! shapes, and returns plain `Vec<f32>` outputs.
//!
//! Threading: the `xla` crate's client is `Rc`-based (not `Send`), so an
//! `Engine` is thread-local by construction.  The actor-mode coordinator
//! gives each node thread its own `Engine` (compiling only the artifacts
//! that node needs); the fused driver uses a single engine on the main
//! thread.  Compilation is cached per engine.

pub mod golden;

use crate::jsonl::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shapes the artifacts were specialized to (manifest `config` block).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelShapes {
    /// Hospital count N.
    pub n: usize,
    /// Input feature dimension.
    pub d: usize,
    /// Hidden-layer width.
    pub hidden: usize,
    /// Minibatch size per step.
    pub m: usize,
    /// Local period Q the scan was lowered for.
    pub q: usize,
    /// Records per shard for the eval/predict artifacts.
    pub shard: usize,
    /// Flat parameter count.
    pub p: usize,
}

/// One artifact's interface.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    /// HLO-text filename inside the artifact dir.
    pub file: String,
    /// Input shapes, in call order.
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes, in result order.
    pub outputs: Vec<Vec<usize>>,
}

/// Parsed `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Artifact directory.
    pub dir: PathBuf,
    /// The specialization shapes.
    pub shapes: ModelShapes,
    /// Artifact interfaces by name.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Golden input/output vectors for the runtime self-test.
    pub goldens: Json,
}

impl Manifest {
    /// Parse `manifest.json` from `dir`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let j = Json::parse_file(&dir.join("manifest.json")).with_context(|| {
            format!(
                "loading manifest from {} — run `make artifacts` first",
                dir.display()
            )
        })?;
        let c = j.get("config")?;
        let shapes = ModelShapes {
            n: c.get("n")?.as_usize()?,
            d: c.get("d")?.as_usize()?,
            hidden: c.get("hidden")?.as_usize()?,
            m: c.get("m")?.as_usize()?,
            q: c.get("q")?.as_usize()?,
            shard: c.get("shard")?.as_usize()?,
            p: c.get("p")?.as_usize()?,
        };
        let mut artifacts = BTreeMap::new();
        for (name, spec) in j.get("artifacts")?.as_obj()? {
            let inputs = spec
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(Json::as_shape)
                .collect::<Result<Vec<_>>>()?;
            let outputs = spec
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(Json::as_shape)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec { file: spec.get("file")?.as_str()?.to_string(), inputs, outputs },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), shapes, artifacts, goldens: j.get("goldens")?.clone() })
    }

    /// Interface of artifact `name`.
    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact `{name}` not in manifest (have: {:?})", self.artifacts.keys().collect::<Vec<_>>()))
    }
}

/// A loaded PJRT engine with a lazy per-artifact executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    exes: RefCell<BTreeMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create an engine over `dir` (must contain `manifest.json`).
    pub fn load(dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Engine { client, manifest, exes: RefCell::new(BTreeMap::new()) })
    }

    /// The specialization shapes.
    pub fn shapes(&self) -> ModelShapes {
        self.manifest.shapes
    }

    /// The parsed manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch cached) an artifact executable.
    pub fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.exes.borrow().get(name) {
            return Ok(std::rc::Rc::clone(exe));
        }
        let spec = self.manifest.spec(name)?;
        let path = self.manifest.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling `{name}`: {e}"))?;
        let rc = std::rc::Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), std::rc::Rc::clone(&rc));
        Ok(rc)
    }

    /// Eagerly compile a set of artifacts (startup cost paid once).
    pub fn warmup(&self, names: &[&str]) -> Result<()> {
        for name in names {
            self.executable(name)?;
        }
        Ok(())
    }

    /// Execute `name` on f32 inputs; shapes validated against the manifest.
    /// Returns one `Vec<f32>` per output (scalars are length-1).
    pub fn execute(&self, name: &str, inputs: &[&[f32]]) -> Result<Vec<Vec<f32>>> {
        let spec = self.manifest.spec(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "`{name}` expects {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (data, shape)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let want: usize = shape.iter().product();
            if data.len() != want {
                bail!(
                    "`{name}` input {i}: expected {:?} = {want} elements, got {}",
                    shape,
                    data.len()
                );
            }
            let lit = xla::Literal::vec1(data);
            let lit = if shape.len() == 1 {
                lit
            } else {
                let dims: Vec<i64> = shape.iter().map(|&x| x as i64).collect();
                lit.reshape(&dims).map_err(|e| anyhow!("reshape input {i} of `{name}`: {e}"))?
            };
            literals.push(lit);
        }
        let exe = self.executable(name)?;
        let out = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing `{name}`: {e}"))?;
        let mut tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching `{name}` result: {e}"))?;
        let parts = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("decomposing `{name}` result: {e}"))?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "`{name}` returned {} outputs, manifest says {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        let mut result = Vec::with_capacity(parts.len());
        for (o, part) in parts.into_iter().enumerate() {
            let v = part
                .to_vec::<f32>()
                .map_err(|e| anyhow!("output {o} of `{name}` to f32: {e}"))?;
            let want: usize = spec.outputs[o].iter().product();
            if v.len() != want {
                bail!(
                    "`{name}` output {o}: expected {:?} = {want} elements, got {}",
                    spec.outputs[o],
                    v.len()
                );
            }
            result.push(v);
        }
        Ok(result)
    }

    /// Sanity-check this engine against the config the caller expects.
    pub fn check_config(&self, n: usize, d: usize, hidden: usize, m: usize, q: usize) -> Result<()> {
        let s = self.manifest.shapes;
        if (s.n, s.d, s.hidden, s.m, s.q) != (n, d, hidden, m, q) {
            bail!(
                "artifacts were compiled for (n={}, d={}, hidden={}, m={}, q={}) but the \
                 experiment wants (n={n}, d={d}, hidden={hidden}, m={m}, q={q}); \
                 re-run `make artifacts N={n} D={d} HIDDEN={hidden} M={m} Q={q}`",
                s.n, s.d, s.hidden, s.m, s.q
            );
        }
        Ok(())
    }
}

/// Default artifacts directory (overridable via config / `--artifacts`).
pub fn default_dir() -> PathBuf {
    PathBuf::from("artifacts")
}

#[cfg(test)]
mod tests {
    use super::*;

    // Engine tests that need real artifacts live in rust/tests/ (integration,
    // gated on `artifacts/manifest.json` existing).  Here: manifest parsing.

    #[test]
    fn manifest_parse_minimal() {
        let dir = std::env::temp_dir().join(format!("decfl_man_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "version": 1,
              "config": {"n":4,"d":6,"hidden":5,"m":3,"q":2,"shard":7,"p":41},
              "artifacts": {
                "grad_step": {"file":"grad_step.hlo.txt","inputs":[[41],[3,6],[3]],"outputs":[[],[41]]}
              },
              "goldens": {"grad_step": {"loss": 0.5}}
            }"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.shapes.p, 41);
        assert_eq!(m.spec("grad_step").unwrap().inputs[1], vec![3, 6]);
        assert!(m.spec("nope").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn manifest_missing_dir_errors_helpfully() {
        let err = Manifest::load(Path::new("/nonexistent/dir")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
