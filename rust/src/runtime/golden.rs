//! Golden-input generators — bit-identical mirrors of `python/compile/aot.py`.
//!
//! The AOT manifest records outputs of each artifact on inputs from these
//! generators; the rust integration tests regenerate the same inputs, run
//! the *compiled artifacts* through PJRT, and assert the outputs match.
//! This closes the loop python-jit ↔ HLO-text ↔ rust-PJRT numerically.

/// `v[i] = ((((offset+i+1) * 2654435761) mod 2^32) / 2^32 - 0.5) * scale`
/// computed in f64, cast to f32 — identical to `aot.golden_vec`.
pub fn golden_vec(offset: u64, count: usize, scale: f64) -> Vec<f32> {
    (0..count as u64)
        .map(|i| {
            let idx = offset + i + 1;
            let hashed = idx.wrapping_mul(2654435761) % (1u64 << 32);
            ((hashed as f64 / 2f64.powi(32) - 0.5) * scale) as f32
        })
        .collect()
}

/// `y[i] = bit0 of the same hash` — identical to `aot.golden_labels`.
pub fn golden_labels(offset: u64, count: usize) -> Vec<f32> {
    (0..count as u64)
        .map(|i| {
            let idx = offset + i + 1;
            let hashed = idx.wrapping_mul(2654435761) % (1u64 << 32);
            (hashed & 1) as f32
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_first_value() {
        // hash(1) = 2654435761; v = (2654435761/2^32 - 0.5) * 1.0
        let expect = (2654435761f64 / 2f64.powi(32) - 0.5) as f32;
        assert_eq!(golden_vec(0, 1, 1.0)[0], expect);
    }

    #[test]
    fn offset_slices_consistent() {
        let long = golden_vec(0, 20, 2.0);
        let tail = golden_vec(10, 10, 2.0);
        assert_eq!(&long[10..], &tail[..]);
    }

    #[test]
    fn labels_binary() {
        let y = golden_labels(0, 1000);
        assert!(y.iter().all(|&v| v == 0.0 || v == 1.0));
        let ones: usize = y.iter().filter(|&&v| v == 1.0).count();
        assert!(ones > 300 && ones < 700, "ones {ones}");
    }

    #[test]
    fn range_bounded() {
        let v = golden_vec(123, 10_000, 2.0);
        assert!(v.iter().all(|x| x.abs() <= 1.0));
    }
}
