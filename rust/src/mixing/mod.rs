//! Mixing-matrix construction and validation (paper Assumption 1).
//!
//! The decentralized updates (eqs. 2–3) combine neighbor iterates with a
//! symmetric doubly stochastic weight matrix `W` whose second-largest
//! eigenvalue magnitude is < 1 on a connected graph.  Three standard
//! constructions are provided; all are validated against Assumption 1 by
//! [`validate`], and the spectral gap `1 - |λ₂|` is exposed because it is the
//! consensus-rate knob the topology ablation (EXP-A2) sweeps.

use crate::graph::Graph;
use crate::linalg::{eig::second_eigenvalue_magnitude, Mat};
use anyhow::{bail, Result};

/// Weighting schemes for building `W` from a graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// Metropolis–Hastings: `w_ij = 1 / (1 + max(deg_i, deg_j))` on edges.
    /// Symmetric, doubly stochastic, positive-semidefinite-ish diagonally
    /// dominant for most graphs; the default everywhere in the paper repro.
    Metropolis,
    /// Lazy Metropolis: `(I + W_mh) / 2` — guarantees all eigenvalues are in
    /// (0, 1], useful when a topology would otherwise put λ_min near -1
    /// (e.g. bipartite-ish structures).
    LazyMetropolis,
    /// Max-degree: `w_ij = 1/(1 + max_deg)` on edges, remainder on diagonal.
    MaxDegree,
}

impl Scheme {
    /// Parse a CLI/TOML mixing-scheme name.
    pub fn parse(name: &str) -> Result<Scheme> {
        Ok(match name {
            "metropolis" => Scheme::Metropolis,
            "lazy" | "lazy-metropolis" => Scheme::LazyMetropolis,
            "maxdeg" | "max-degree" => Scheme::MaxDegree,
            other => bail!("unknown mixing scheme `{other}` (metropolis|lazy|maxdeg)"),
        })
    }
}

/// Build the mixing matrix for `g` under `scheme`.
pub fn build(g: &Graph, scheme: Scheme) -> Mat {
    let n = g.n();
    let mut w = Mat::zeros(n, n);
    match scheme {
        Scheme::Metropolis | Scheme::LazyMetropolis => {
            for i in 0..n {
                for &j in g.neighbors(i) {
                    w[(i, j)] = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
                }
            }
            for i in 0..n {
                let off: f64 = g.neighbors(i).iter().map(|&j| w[(i, j)]).sum();
                w[(i, i)] = 1.0 - off;
            }
            if scheme == Scheme::LazyMetropolis {
                for i in 0..n {
                    for j in 0..n {
                        w[(i, j)] *= 0.5;
                    }
                    w[(i, i)] += 0.5;
                }
            }
        }
        Scheme::MaxDegree => {
            let dmax = (0..n).map(|i| g.degree(i)).max().unwrap_or(0) as f64;
            let wij = 1.0 / (1.0 + dmax);
            for i in 0..n {
                for &j in g.neighbors(i) {
                    w[(i, j)] = wij;
                }
                w[(i, i)] = 1.0 - g.degree(i) as f64 * wij;
            }
        }
    }
    w
}

/// Validation report for Assumption 1.
#[derive(Clone, Debug)]
pub struct Validation {
    /// Is `W` symmetric?
    pub symmetric: bool,
    /// Does every row sum to 1?
    pub rows_stochastic: bool,
    /// Are all entries non-negative?
    pub nonnegative: bool,
    /// `|λ₂|` — the consensus contraction factor.
    pub second_eig: f64,
    /// `1 − |λ₂|`.
    pub spectral_gap: f64,
}

impl Validation {
    /// Does Assumption 1 hold?
    pub fn holds(&self) -> bool {
        self.symmetric && self.rows_stochastic && self.nonnegative && self.second_eig < 1.0
    }
}

/// Check `W` against Assumption 1: symmetric, `W 1 = 1`, `|λ₂| < 1`.
pub fn validate(w: &Mat) -> Validation {
    let n = w.rows;
    let symmetric = w.is_symmetric(1e-12);
    let rows_stochastic = (0..n).all(|i| (w.row(i).iter().sum::<f64>() - 1.0).abs() < 1e-9);
    let nonnegative = w.data.iter().all(|&x| x >= -1e-12);
    let second_eig = second_eigenvalue_magnitude(w);
    Validation {
        symmetric,
        rows_stochastic,
        nonnegative,
        second_eig,
        spectral_gap: 1.0 - second_eig,
    }
}

/// Flatten to f32 row-major (what the PJRT artifacts consume).
pub fn to_f32(w: &Mat) -> Vec<f32> {
    w.data.iter().map(|&x| x as f32).collect()
}

/// Degree-sparse (CSR) view of an f32 mixing matrix: per row, the
/// `(column, weight)` pairs of exactly its nonzero entries, columns
/// ascending.  Because the dense combine kernel skips zero weights while
/// scanning columns in ascending order, combining over a `SparseW` row
/// visits the same entries in the same order — bitwise-identical results —
/// while the per-node gossip cost drops from O(n·p) to O(deg·p).
///
/// Built from the *f32* dense matrix (the form the kernels consume), so the
/// zero test matches the dense loop's exactly, including any f64→f32
/// underflow to zero during conversion.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseW {
    n: usize,
    /// Row start offsets, length `n + 1`.
    off: Vec<u32>,
    /// Column indices, ascending within each row.
    idx: Vec<u32>,
    /// Weights, parallel to `idx`.
    val: Vec<f32>,
}

impl SparseW {
    /// Build from a row-major dense `[n, n]` f32 matrix, keeping nonzeros.
    pub fn from_dense(n: usize, dense: &[f32]) -> Self {
        assert_eq!(dense.len(), n * n, "dense W must be n x n");
        assert!(n <= u32::MAX as usize, "SparseW indexes rows with u32");
        let mut off = Vec::with_capacity(n + 1);
        let mut idx = Vec::new();
        let mut val = Vec::new();
        off.push(0u32);
        for i in 0..n {
            for (j, &w) in dense[i * n..(i + 1) * n].iter().enumerate() {
                if w != 0.0 {
                    idx.push(j as u32);
                    val.push(w);
                }
            }
            off.push(idx.len() as u32);
        }
        SparseW { n, off, idx, val }
    }

    /// Build from the f64 `Mat`, converting through [`to_f32`] so the kept
    /// entries match the dense-f32 path bit for bit.
    pub fn from_mat(w: &Mat) -> Self {
        assert_eq!(w.rows, w.cols, "mixing matrix must be square");
        Self::from_dense(w.rows, &to_f32(w))
    }

    /// Matrix dimension n.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Total nonzero count.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Row `i`'s `(columns, weights)`, columns ascending.
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.off[i] as usize, self.off[i + 1] as usize);
        (&self.idx[a..b], &self.val[a..b])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;
    use crate::rng::Pcg64;
    use crate::testutil;

    fn build_graph(topo: &Topology, n: usize, seed: u64) -> Graph {
        Graph::build(topo, n, &mut Pcg64::seed(seed)).unwrap()
    }

    #[test]
    fn metropolis_ring_known_weights() {
        let g = build_graph(&Topology::Ring, 6, 0);
        let w = build(&g, Scheme::Metropolis);
        // all degrees 2 → off-diag weight 1/3, diagonal 1/3
        for i in 0..6 {
            assert!((w[(i, i)] - 1.0 / 3.0).abs() < 1e-12);
            for &j in g.neighbors(i) {
                assert!((w[(i, j)] - 1.0 / 3.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn all_schemes_satisfy_assumption_1() {
        let topologies = [
            Topology::Ring,
            Topology::Path,
            Topology::Complete,
            Topology::Star,
            Topology::ErdosRenyi { p: 0.3 },
            Topology::RandomGeometric { radius: 0.35 },
        ];
        for (ti, topo) in topologies.iter().enumerate() {
            for scheme in [Scheme::Metropolis, Scheme::LazyMetropolis, Scheme::MaxDegree] {
                let g = build_graph(topo, 20, ti as u64);
                let w = build(&g, scheme);
                let v = validate(&w);
                assert!(v.holds(), "{topo:?} {scheme:?}: {v:?}");
            }
        }
    }

    #[test]
    fn lazy_has_nonnegative_spectrum() {
        let g = build_graph(&Topology::Ring, 8, 0); // even ring: λ_min(W_mh) can be negative
        let w = build(&g, Scheme::LazyMetropolis);
        let eig = crate::linalg::sym_eig(&w);
        assert!(eig.values.iter().all(|&v| v > -1e-12), "{:?}", eig.values);
    }

    #[test]
    fn complete_graph_metropolis_is_uniform_averaging() {
        let g = build_graph(&Topology::Complete, 5, 0);
        let w = build(&g, Scheme::Metropolis);
        for i in 0..5 {
            for j in 0..5 {
                assert!((w[(i, j)] - 0.2).abs() < 1e-12);
            }
        }
        assert!(validate(&w).second_eig < 1e-10);
    }

    #[test]
    fn denser_graph_smaller_second_eig() {
        let ring = build(&build_graph(&Topology::Ring, 20, 0), Scheme::Metropolis);
        let complete = build(&build_graph(&Topology::Complete, 20, 0), Scheme::Metropolis);
        let er = build(&build_graph(&Topology::ErdosRenyi { p: 0.4 }, 20, 1), Scheme::Metropolis);
        let l_ring = validate(&ring).second_eig;
        let l_er = validate(&er).second_eig;
        let l_complete = validate(&complete).second_eig;
        assert!(l_complete < l_er && l_er < l_ring, "{l_complete} {l_er} {l_ring}");
    }

    #[test]
    fn mixing_contracts_disagreement_property() {
        // ||W x - x̄ 1|| <= |λ₂| ||x - x̄ 1|| — the consensus contraction
        testutil::check("mixing contraction", 16, 5, |rng| {
            let n = rng.range(3, 25);
            let g = Graph::build(&Topology::ErdosRenyi { p: 0.4 }, n, rng)
                .map_err(|e| e.to_string())?;
            let w = build(&g, Scheme::Metropolis);
            let lam2 = validate(&w).second_eig;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let xbar = crate::linalg::mean(&x);
            let centered: Vec<f64> = x.iter().map(|v| v - xbar).collect();
            let wx = w.matvec(&x);
            let wx_centered: Vec<f64> = wx.iter().map(|v| v - xbar).collect();
            let before = crate::linalg::norm2(&centered);
            let after = crate::linalg::norm2(&wx_centered);
            if after <= lam2 * before + 1e-9 {
                Ok(())
            } else {
                Err(format!("no contraction: {after} > {lam2} * {before}"))
            }
        });
    }

    #[test]
    fn doubly_stochastic_property() {
        testutil::check("column sums", 16, 6, |rng| {
            let n = rng.range(3, 25);
            let g = Graph::build(&Topology::ErdosRenyi { p: 0.35 }, n, rng)
                .map_err(|e| e.to_string())?;
            for scheme in [Scheme::Metropolis, Scheme::LazyMetropolis, Scheme::MaxDegree] {
                let w = build(&g, scheme);
                for j in 0..n {
                    let col: f64 = (0..n).map(|i| w[(i, j)]).sum();
                    if (col - 1.0).abs() > 1e-9 {
                        return Err(format!("{scheme:?} col {j} sums to {col}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn to_f32_roundtrip() {
        let g = build_graph(&Topology::Ring, 4, 0);
        let w = build(&g, Scheme::Metropolis);
        let f = to_f32(&w);
        assert_eq!(f.len(), 16);
        assert!((f[0] as f64 - w[(0, 0)]).abs() < 1e-7);
    }

    #[test]
    fn sparse_w_rows_are_ascending_nonzeros() {
        let g = build_graph(&Topology::Ring, 5, 0);
        let w = build(&g, Scheme::Metropolis);
        let dense = to_f32(&w);
        let sp = SparseW::from_mat(&w);
        assert_eq!(sp.n(), 5);
        // ring: every row has self + 2 neighbors
        assert_eq!(sp.nnz(), 5 * 3);
        for i in 0..5 {
            let (idx, val) = sp.row(i);
            assert_eq!(idx.len(), 3);
            assert!(idx.windows(2).all(|p| p[0] < p[1]), "row {i} not ascending");
            for (&j, &v) in idx.iter().zip(val) {
                assert_eq!(v, dense[i * 5 + j as usize], "row {i} col {j}");
                assert_ne!(v, 0.0);
            }
            // zeros are excluded
            assert_eq!(
                idx.len(),
                dense[i * 5..(i + 1) * 5].iter().filter(|&&v| v != 0.0).count()
            );
        }
        // SparseW::from_dense on the f32 matrix agrees with from_mat
        assert_eq!(sp, SparseW::from_dense(5, &dense));
    }

    #[test]
    fn scheme_parse() {
        assert_eq!(Scheme::parse("metropolis").unwrap(), Scheme::Metropolis);
        assert_eq!(Scheme::parse("lazy").unwrap(), Scheme::LazyMetropolis);
        assert_eq!(Scheme::parse("maxdeg").unwrap(), Scheme::MaxDegree);
        assert!(Scheme::parse("nope").is_err());
    }
}
