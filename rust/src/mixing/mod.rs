//! Mixing-matrix construction and validation (paper Assumption 1).
//!
//! The decentralized updates (eqs. 2–3) combine neighbor iterates with a
//! symmetric doubly stochastic weight matrix `W` whose second-largest
//! eigenvalue magnitude is < 1 on a connected graph.  Three standard
//! constructions are provided; all are validated against Assumption 1 by
//! [`validate`], and the spectral gap `1 - |λ₂|` is exposed because it is the
//! consensus-rate knob the topology ablation (EXP-A2) sweeps.

use crate::graph::Graph;
use crate::linalg::{
    eig::{second_eigenvalue_magnitude, PowerIterOpts},
    second_eig_magnitude_power_opts, Mat,
};
use anyhow::{bail, Result};

/// Below this n, [`validate_sparse`] cross-checks |λ₂| with the dense Jacobi
/// oracle; above it, only the sparse power iteration runs.
const JACOBI_ORACLE_MAX_N: usize = 256;

/// Weighting schemes for building `W` from a graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scheme {
    /// Metropolis–Hastings: `w_ij = 1 / (1 + max(deg_i, deg_j))` on edges.
    /// Symmetric, doubly stochastic, positive-semidefinite-ish diagonally
    /// dominant for most graphs; the default everywhere in the paper repro.
    Metropolis,
    /// Lazy Metropolis: `(I + W_mh) / 2` — guarantees all eigenvalues are in
    /// (0, 1], useful when a topology would otherwise put λ_min near -1
    /// (e.g. bipartite-ish structures).
    LazyMetropolis,
    /// Max-degree: `w_ij = 1/(1 + max_deg)` on edges, remainder on diagonal.
    MaxDegree,
}

impl Scheme {
    /// Parse a CLI/TOML mixing-scheme name.
    pub fn parse(name: &str) -> Result<Scheme> {
        Ok(match name {
            "metropolis" => Scheme::Metropolis,
            "lazy" | "lazy-metropolis" => Scheme::LazyMetropolis,
            "maxdeg" | "max-degree" => Scheme::MaxDegree,
            other => bail!("unknown mixing scheme `{other}` (metropolis|lazy|maxdeg)"),
        })
    }
}

/// Build the mixing matrix for `g` under `scheme`.
pub fn build(g: &Graph, scheme: Scheme) -> Mat {
    let n = g.n();
    let mut w = Mat::zeros(n, n);
    match scheme {
        Scheme::Metropolis | Scheme::LazyMetropolis => {
            for i in 0..n {
                for &j in g.neighbors(i) {
                    w[(i, j)] = 1.0 / (1.0 + g.degree(i).max(g.degree(j)) as f64);
                }
            }
            for i in 0..n {
                let off: f64 = g.neighbors(i).iter().map(|&j| w[(i, j)]).sum();
                w[(i, i)] = 1.0 - off;
            }
            if scheme == Scheme::LazyMetropolis {
                for i in 0..n {
                    for j in 0..n {
                        w[(i, j)] *= 0.5;
                    }
                    w[(i, i)] += 0.5;
                }
            }
        }
        Scheme::MaxDegree => {
            let dmax = (0..n).map(|i| g.degree(i)).max().unwrap_or(0) as f64;
            let wij = 1.0 / (1.0 + dmax);
            for i in 0..n {
                for &j in g.neighbors(i) {
                    w[(i, j)] = wij;
                }
                w[(i, i)] = 1.0 - g.degree(i) as f64 * wij;
            }
        }
    }
    w
}

/// How much of Assumption 1 to verify when building a schedule.  The exact
/// structural checks (symmetry, row sums, non-negativity) are O(E) and run
/// at *every* level; only the spectral-gap estimate — 581 s of power
/// iteration at n = 10⁵ per BENCH_6 — is negotiable.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ValidateLevel {
    /// Structural checks + |λ₂| at full precision (Jacobi oracle below
    /// [`JACOBI_ORACLE_MAX_N`], tight power iteration above).  The default.
    Full,
    /// Structural checks + a budgeted power iteration
    /// ([`PowerIterOpts::approx`]) — enough digits to decide λ₂ < 1 and
    /// report a usable gap, orders of magnitude cheaper at large n.
    Approx,
    /// Structural checks only; `second_eig`/`spectral_gap` are NaN and
    /// [`Validation::holds`] no longer gates on the spectrum.  For large-n
    /// schedule construction where the scheme guarantees λ₂ < 1 on a
    /// connected graph by construction.
    Skip,
}

impl ValidateLevel {
    /// Parse a CLI/TOML validation-level name.
    pub fn parse(name: &str) -> Result<ValidateLevel> {
        Ok(match name {
            "full" => ValidateLevel::Full,
            "approx" => ValidateLevel::Approx,
            "skip" => ValidateLevel::Skip,
            other => bail!("unknown net.validate level `{other}` (full|approx|skip)"),
        })
    }
}

/// Validation report for Assumption 1.
#[derive(Clone, Debug)]
pub struct Validation {
    /// Is `W` symmetric?
    pub symmetric: bool,
    /// Does every row sum to 1?
    pub rows_stochastic: bool,
    /// Are all entries non-negative?
    pub nonnegative: bool,
    /// `|λ₂|` — the consensus contraction factor.  NaN when the spectral
    /// check was skipped ([`ValidateLevel::Skip`]).
    pub second_eig: f64,
    /// `1 − |λ₂|`.  NaN when the spectral check was skipped.
    pub spectral_gap: f64,
    /// Was |λ₂| actually estimated?  False only under
    /// [`ValidateLevel::Skip`], where [`Validation::holds`] gates on the
    /// structural checks alone.
    pub spectral_checked: bool,
}

impl Validation {
    /// Does Assumption 1 hold?  (Structural checks always; the spectral
    /// condition only when it was computed — note `NaN < 1.0` is false, so
    /// gating on a skipped estimate would reject every matrix.)
    pub fn holds(&self) -> bool {
        self.symmetric
            && self.rows_stochastic
            && self.nonnegative
            && (!self.spectral_checked || self.second_eig < 1.0)
    }
}

/// Build the mixing matrix directly in CSR form, skipping the dense `Mat`.
/// Entry-for-entry bitwise identical to `SparseW::from_mat(&build(g, s))`
/// (same f64 op order per row, same f64→f32 cast, same nonzero filter) —
/// pinned by the property tests — but O(E) in time and memory, so it is the
/// only W constructor usable at 10⁵⁺ nodes.
pub fn build_sparse(g: &Graph, scheme: Scheme) -> SparseW {
    let mut out = SparseW::empty();
    build_sparse_into(g, scheme, &mut out);
    out
}

/// [`build_sparse`] into caller-owned storage (grow-only; no allocation once
/// `out`'s buffers have reached the graph's size).
pub fn build_sparse_into(g: &Graph, scheme: Scheme, out: &mut SparseW) {
    let n = g.n();
    out.reset(n);
    out.reserve_rows_nnz(n, 2 * g.edge_count() + n);
    // per row: f64 weights in the dense build's exact op order (ascending
    // neighbors; diagonal = 1 - sum), diagonal merged into sorted position,
    // each entry cast to f32 and kept iff nonzero — matching `from_dense`
    match scheme {
        Scheme::Metropolis | Scheme::LazyMetropolis => {
            let lazy = scheme == Scheme::LazyMetropolis;
            for i in 0..n {
                let di = g.degree(i);
                let mut off_sum = 0.0f64;
                for &j in g.neighbors(i) {
                    off_sum += 1.0 / (1.0 + di.max(g.degree(j)) as f64);
                }
                let diag = if lazy { (1.0 - off_sum) * 0.5 + 0.5 } else { 1.0 - off_sum };
                let mut placed = false;
                for &j in g.neighbors(i) {
                    if !placed && j > i {
                        out.push_entry(i as u32, diag as f32);
                        placed = true;
                    }
                    let w = 1.0 / (1.0 + di.max(g.degree(j)) as f64);
                    out.push_entry(j as u32, if lazy { (w * 0.5) as f32 } else { w as f32 });
                }
                if !placed {
                    out.push_entry(i as u32, diag as f32);
                }
                out.seal_row();
            }
        }
        Scheme::MaxDegree => {
            let dmax = (0..n).map(|i| g.degree(i)).max().unwrap_or(0) as f64;
            let wij = 1.0 / (1.0 + dmax);
            for i in 0..n {
                let diag = 1.0 - g.degree(i) as f64 * wij;
                let mut placed = false;
                for &j in g.neighbors(i) {
                    if !placed && j > i {
                        out.push_entry(i as u32, diag as f32);
                        placed = true;
                    }
                    out.push_entry(j as u32, wij as f32);
                }
                if !placed {
                    out.push_entry(i as u32, diag as f32);
                }
                out.seal_row();
            }
        }
    }
}

/// Check `W` against Assumption 1: symmetric, `W 1 = 1`, `|λ₂| < 1`.
pub fn validate(w: &Mat) -> Validation {
    let n = w.rows;
    let symmetric = w.is_symmetric(1e-12);
    let rows_stochastic = (0..n).all(|i| (w.row(i).iter().sum::<f64>() - 1.0).abs() < 1e-9);
    let nonnegative = w.data.iter().all(|&x| x >= -1e-12);
    let second_eig = second_eigenvalue_magnitude(w);
    Validation {
        symmetric,
        rows_stochastic,
        nonnegative,
        second_eig,
        spectral_gap: 1.0 - second_eig,
        spectral_checked: true,
    }
}

/// Check a CSR `W` against Assumption 1 without densifying: symmetry by
/// binary-searching the transposed entry (weights must match exactly — both
/// sides cast from the same f64 formula), row sums in f64 with an
/// entry-count-scaled f32 tolerance, and |λ₂| from the Jacobi oracle below
/// [`JACOBI_ORACLE_MAX_N`] or sparse power iteration above it.
///
/// This is [`validate_sparse_with`] at [`ValidateLevel::Full`] — the default
/// everywhere a config does not say otherwise.
pub fn validate_sparse(w: &SparseW) -> Validation {
    validate_sparse_with(w, ValidateLevel::Full)
}

/// [`validate_sparse`] with an explicit effort level for the spectral part
/// (`net.validate`): the exact symmetry / row-sum / non-negativity scan
/// always runs; `level` picks the |λ₂| budget or skips it (see
/// [`ValidateLevel`]).
pub fn validate_sparse_with(w: &SparseW, level: ValidateLevel) -> Validation {
    let n = w.n();
    let mut symmetric = true;
    let mut rows_stochastic = true;
    let mut nonnegative = true;
    for i in 0..n {
        let (idx, val) = w.row(i);
        let mut sum = 0.0f64;
        for (&j, &v) in idx.iter().zip(val) {
            sum += v as f64;
            if (v as f64) < -1e-12 {
                nonnegative = false;
            }
            let (jid, jval) = w.row(j as usize);
            match jid.binary_search(&(i as u32)) {
                Ok(p) if jval[p] == v => {}
                _ => symmetric = false,
            }
        }
        // f32 weights: each entry carries ~2⁻²⁴ relative rounding
        if (sum - 1.0).abs() > 1e-6 + idx.len() as f64 * 1e-7 {
            rows_stochastic = false;
        }
    }
    let (second_eig, spectral_checked) = match level {
        ValidateLevel::Full => {
            let l2 = if n <= JACOBI_ORACLE_MAX_N {
                second_eigenvalue_magnitude(&w.to_mat())
            } else {
                w.second_eig_magnitude()
            };
            (l2, true)
        }
        // budgeted power iteration at any n — never the O(n³) oracle
        ValidateLevel::Approx => (w.second_eig_magnitude_opts(PowerIterOpts::approx()), true),
        ValidateLevel::Skip => (f64::NAN, false),
    };
    Validation {
        symmetric,
        rows_stochastic,
        nonnegative,
        second_eig,
        spectral_gap: 1.0 - second_eig,
        spectral_checked,
    }
}

/// Flatten to f32 row-major (what the PJRT artifacts consume).
pub fn to_f32(w: &Mat) -> Vec<f32> {
    w.data.iter().map(|&x| x as f32).collect()
}

/// Degree-sparse (CSR) view of an f32 mixing matrix: per row, the
/// `(column, weight)` pairs of exactly its nonzero entries, columns
/// ascending.  Because the dense combine kernel skips zero weights while
/// scanning columns in ascending order, combining over a `SparseW` row
/// visits the same entries in the same order — bitwise-identical results —
/// while the per-node gossip cost drops from O(n·p) to O(deg·p).
///
/// Built from the *f32* dense matrix (the form the kernels consume), so the
/// zero test matches the dense loop's exactly, including any f64→f32
/// underflow to zero during conversion.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseW {
    n: usize,
    /// Row start offsets, length `n + 1`.
    off: Vec<u32>,
    /// Column indices, ascending within each row.
    idx: Vec<u32>,
    /// Weights, parallel to `idx`.
    val: Vec<f32>,
}

impl SparseW {
    /// Build from a row-major dense `[n, n]` f32 matrix, keeping nonzeros.
    pub fn from_dense(n: usize, dense: &[f32]) -> Self {
        assert_eq!(dense.len(), n * n, "dense W must be n x n");
        assert!(n <= u32::MAX as usize, "SparseW indexes rows with u32");
        let mut off = Vec::with_capacity(n + 1);
        let mut idx = Vec::new();
        let mut val = Vec::new();
        off.push(0u32);
        for i in 0..n {
            for (j, &w) in dense[i * n..(i + 1) * n].iter().enumerate() {
                if w != 0.0 {
                    idx.push(j as u32);
                    val.push(w);
                }
            }
            off.push(idx.len() as u32);
        }
        SparseW { n, off, idx, val }
    }

    /// Build from the f64 `Mat`, converting through [`to_f32`] so the kept
    /// entries match the dense-f32 path bit for bit.
    pub fn from_mat(w: &Mat) -> Self {
        assert_eq!(w.rows, w.cols, "mixing matrix must be square");
        Self::from_dense(w.rows, &to_f32(w))
    }

    /// Matrix dimension n.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Total nonzero count.
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Row `i`'s `(columns, weights)`, columns ascending.
    pub fn row(&self, i: usize) -> (&[u32], &[f32]) {
        let (a, b) = (self.off[i] as usize, self.off[i + 1] as usize);
        (&self.idx[a..b], &self.val[a..b])
    }

    /// Empty 0×0 matrix, ready for [`SparseW::reset`] row-by-row building.
    pub fn empty() -> Self {
        SparseW { n: 0, off: vec![0], idx: Vec::new(), val: Vec::new() }
    }

    /// Start over as an n×n matrix with no rows sealed yet (grow-only: the
    /// existing buffers are reused).
    pub(crate) fn reset(&mut self, n: usize) {
        assert!(n <= u32::MAX as usize, "SparseW indexes rows with u32");
        self.n = n;
        self.off.clear();
        self.off.push(0);
        self.idx.clear();
        self.val.clear();
    }

    /// Pre-size the buffers for `n` rows / `nnz` entries so subsequent
    /// builds stay allocation-free.
    pub(crate) fn reserve_rows_nnz(&mut self, n: usize, nnz: usize) {
        self.off.reserve((n + 1).saturating_sub(self.off.len()));
        self.idx.reserve(nnz.saturating_sub(self.idx.len()));
        self.val.reserve(nnz.saturating_sub(self.val.len()));
    }

    /// Append one entry to the row under construction; zeros are dropped to
    /// match the `from_dense` nonzero filter.  Columns must arrive ascending.
    pub(crate) fn push_entry(&mut self, j: u32, v: f32) {
        if v != 0.0 {
            self.idx.push(j);
            self.val.push(v);
        }
    }

    /// Close the row under construction.
    pub(crate) fn seal_row(&mut self) {
        self.off.push(self.idx.len() as u32);
    }

    /// Overwrite self with `src`'s contents, reusing capacity (no allocation
    /// once the buffers have grown to `src`'s size).
    pub(crate) fn copy_from(&mut self, src: &SparseW) {
        self.n = src.n;
        self.off.clear();
        self.off.extend_from_slice(&src.off);
        self.idx.clear();
        self.idx.extend_from_slice(&src.idx);
        self.val.clear();
        self.val.extend_from_slice(&src.val);
    }

    /// Scatter to a dense row-major f32 matrix.  Small-n only (gated): this
    /// is the debug/test conversion, never the hot path.
    pub fn to_dense(&self) -> Vec<f32> {
        assert!(
            self.n <= crate::graph::SMALL_N_LIMIT,
            "SparseW::to_dense is gated to n <= {} (got n = {})",
            crate::graph::SMALL_N_LIMIT,
            self.n
        );
        let mut out = vec![0.0f32; self.n * self.n];
        for i in 0..self.n {
            let (idx, val) = self.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                out[i * self.n + j as usize] = v;
            }
        }
        out
    }

    /// Lift to the f64 `Mat` the dense analysis substrates consume — entries
    /// are the stored f32 weights, exactly.  Small-n only (gated).
    pub fn to_mat(&self) -> Mat {
        assert!(
            self.n <= crate::graph::SMALL_N_LIMIT,
            "SparseW::to_mat is gated to n <= {} (got n = {})",
            crate::graph::SMALL_N_LIMIT,
            self.n
        );
        let mut out = Mat::zeros(self.n, self.n);
        for i in 0..self.n {
            let (idx, val) = self.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                out[(i, j as usize)] = v as f64;
            }
        }
        out
    }

    /// |λ₂| via sparse power iteration (f64 matvec over the f32 weights):
    /// the large-n spectral-gap path.  For the Jacobi-oracle comparison use
    /// `second_eigenvalue_magnitude(&w.to_mat())` at small n.
    pub fn second_eig_magnitude(&self) -> f64 {
        self.second_eig_magnitude_opts(PowerIterOpts::default())
    }

    /// [`SparseW::second_eig_magnitude`] under an explicit iteration budget —
    /// the `net.validate = approx` path, where large-n schedule construction
    /// trades spectral digits for wall-clock (BENCH_6: 581 s at n = 10⁵ under
    /// the default budget).
    pub fn second_eig_magnitude_opts(&self, opts: PowerIterOpts) -> f64 {
        second_eig_magnitude_power_opts(self.n, opts, |x, out| {
            for i in 0..self.n {
                let (idx, val) = self.row(i);
                let mut acc = 0.0f64;
                for (&j, &v) in idx.iter().zip(val) {
                    acc += v as f64 * x[j as usize];
                }
                out[i] = acc;
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;
    use crate::rng::Pcg64;
    use crate::testutil;

    fn build_graph(topo: &Topology, n: usize, seed: u64) -> Graph {
        Graph::build(topo, n, &mut Pcg64::seed(seed)).unwrap()
    }

    #[test]
    fn metropolis_ring_known_weights() {
        let g = build_graph(&Topology::Ring, 6, 0);
        let w = build(&g, Scheme::Metropolis);
        // all degrees 2 → off-diag weight 1/3, diagonal 1/3
        for i in 0..6 {
            assert!((w[(i, i)] - 1.0 / 3.0).abs() < 1e-12);
            for &j in g.neighbors(i) {
                assert!((w[(i, j)] - 1.0 / 3.0).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn all_schemes_satisfy_assumption_1() {
        let topologies = [
            Topology::Ring,
            Topology::Path,
            Topology::Complete,
            Topology::Star,
            Topology::ErdosRenyi { p: 0.3 },
            Topology::RandomGeometric { radius: 0.35 },
        ];
        for (ti, topo) in topologies.iter().enumerate() {
            for scheme in [Scheme::Metropolis, Scheme::LazyMetropolis, Scheme::MaxDegree] {
                let g = build_graph(topo, 20, ti as u64);
                let w = build(&g, scheme);
                let v = validate(&w);
                assert!(v.holds(), "{topo:?} {scheme:?}: {v:?}");
            }
        }
    }

    #[test]
    fn lazy_has_nonnegative_spectrum() {
        let g = build_graph(&Topology::Ring, 8, 0); // even ring: λ_min(W_mh) can be negative
        let w = build(&g, Scheme::LazyMetropolis);
        let eig = crate::linalg::sym_eig(&w);
        assert!(eig.values.iter().all(|&v| v > -1e-12), "{:?}", eig.values);
    }

    #[test]
    fn complete_graph_metropolis_is_uniform_averaging() {
        let g = build_graph(&Topology::Complete, 5, 0);
        let w = build(&g, Scheme::Metropolis);
        for i in 0..5 {
            for j in 0..5 {
                assert!((w[(i, j)] - 0.2).abs() < 1e-12);
            }
        }
        assert!(validate(&w).second_eig < 1e-10);
    }

    #[test]
    fn denser_graph_smaller_second_eig() {
        let ring = build(&build_graph(&Topology::Ring, 20, 0), Scheme::Metropolis);
        let complete = build(&build_graph(&Topology::Complete, 20, 0), Scheme::Metropolis);
        let er = build(&build_graph(&Topology::ErdosRenyi { p: 0.4 }, 20, 1), Scheme::Metropolis);
        let l_ring = validate(&ring).second_eig;
        let l_er = validate(&er).second_eig;
        let l_complete = validate(&complete).second_eig;
        assert!(l_complete < l_er && l_er < l_ring, "{l_complete} {l_er} {l_ring}");
    }

    #[test]
    fn mixing_contracts_disagreement_property() {
        // ||W x - x̄ 1|| <= |λ₂| ||x - x̄ 1|| — the consensus contraction
        testutil::check("mixing contraction", 16, 5, |rng| {
            let n = rng.range(3, 25);
            let g = Graph::build(&Topology::ErdosRenyi { p: 0.4 }, n, rng)
                .map_err(|e| e.to_string())?;
            let w = build(&g, Scheme::Metropolis);
            let lam2 = validate(&w).second_eig;
            let x: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let xbar = crate::linalg::mean(&x);
            let centered: Vec<f64> = x.iter().map(|v| v - xbar).collect();
            let wx = w.matvec(&x);
            let wx_centered: Vec<f64> = wx.iter().map(|v| v - xbar).collect();
            let before = crate::linalg::norm2(&centered);
            let after = crate::linalg::norm2(&wx_centered);
            if after <= lam2 * before + 1e-9 {
                Ok(())
            } else {
                Err(format!("no contraction: {after} > {lam2} * {before}"))
            }
        });
    }

    #[test]
    fn doubly_stochastic_property() {
        testutil::check("column sums", 16, 6, |rng| {
            let n = rng.range(3, 25);
            let g = Graph::build(&Topology::ErdosRenyi { p: 0.35 }, n, rng)
                .map_err(|e| e.to_string())?;
            for scheme in [Scheme::Metropolis, Scheme::LazyMetropolis, Scheme::MaxDegree] {
                let w = build(&g, scheme);
                for j in 0..n {
                    let col: f64 = (0..n).map(|i| w[(i, j)]).sum();
                    if (col - 1.0).abs() > 1e-9 {
                        return Err(format!("{scheme:?} col {j} sums to {col}"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn to_f32_roundtrip() {
        let g = build_graph(&Topology::Ring, 4, 0);
        let w = build(&g, Scheme::Metropolis);
        let f = to_f32(&w);
        assert_eq!(f.len(), 16);
        assert!((f[0] as f64 - w[(0, 0)]).abs() < 1e-7);
    }

    #[test]
    fn sparse_w_rows_are_ascending_nonzeros() {
        let g = build_graph(&Topology::Ring, 5, 0);
        let w = build(&g, Scheme::Metropolis);
        let dense = to_f32(&w);
        let sp = SparseW::from_mat(&w);
        assert_eq!(sp.n(), 5);
        // ring: every row has self + 2 neighbors
        assert_eq!(sp.nnz(), 5 * 3);
        for i in 0..5 {
            let (idx, val) = sp.row(i);
            assert_eq!(idx.len(), 3);
            assert!(idx.windows(2).all(|p| p[0] < p[1]), "row {i} not ascending");
            for (&j, &v) in idx.iter().zip(val) {
                assert_eq!(v, dense[i * 5 + j as usize], "row {i} col {j}");
                assert_ne!(v, 0.0);
            }
            // zeros are excluded
            assert_eq!(
                idx.len(),
                dense[i * 5..(i + 1) * 5].iter().filter(|&&v| v != 0.0).count()
            );
        }
        // SparseW::from_dense on the f32 matrix agrees with from_mat
        assert_eq!(sp, SparseW::from_dense(5, &dense));
    }

    #[test]
    fn scheme_parse() {
        assert_eq!(Scheme::parse("metropolis").unwrap(), Scheme::Metropolis);
        assert_eq!(Scheme::parse("lazy").unwrap(), Scheme::LazyMetropolis);
        assert_eq!(Scheme::parse("maxdeg").unwrap(), Scheme::MaxDegree);
        assert!(Scheme::parse("nope").is_err());
    }

    #[test]
    fn csr_build_bitwise_equals_dense_build_across_families_and_schemes() {
        // satellite pin: the sparse-native constructor is entry-for-entry
        // bitwise identical to densify-then-sparsify, for every family ×
        // scheme pair (SparseW derives PartialEq over off/idx/val)
        let fams = [
            Topology::Ring,
            Topology::Path,
            Topology::Complete,
            Topology::Star,
            Topology::Torus { rows: 4, cols: 5 },
            Topology::ErdosRenyi { p: 0.3 },
            Topology::RandomGeometric { radius: 0.35 },
            Topology::SmallWorld { k: 4, beta: 0.2 },
            Topology::KNearest { k: 3 },
        ];
        for (ti, topo) in fams.iter().enumerate() {
            for scheme in [Scheme::Metropolis, Scheme::LazyMetropolis, Scheme::MaxDegree] {
                for seed in 0..3 {
                    let g = build_graph(topo, 20, 50 + 10 * ti as u64 + seed);
                    let via_dense = SparseW::from_mat(&build(&g, scheme));
                    let direct = build_sparse(&g, scheme);
                    assert_eq!(direct, via_dense, "{topo:?} {scheme:?} seed {seed}");
                    // and the into-variant reuses storage without divergence
                    let mut reused = SparseW::empty();
                    build_sparse_into(&g, scheme, &mut reused);
                    build_sparse_into(&g, scheme, &mut reused);
                    assert_eq!(reused, via_dense, "{topo:?} {scheme:?} seed {seed}: reuse");
                }
            }
        }
    }

    #[test]
    fn validate_sparse_agrees_with_dense_validate() {
        for scheme in [Scheme::Metropolis, Scheme::LazyMetropolis, Scheme::MaxDegree] {
            let g = build_graph(&Topology::ErdosRenyi { p: 0.3 }, 20, 7);
            let w = build(&g, scheme);
            let sp = build_sparse(&g, scheme);
            let vd = validate(&w);
            let vs = validate_sparse(&sp);
            assert!(vs.holds(), "{scheme:?}: {vs:?}");
            assert!(vs.symmetric && vs.rows_stochastic && vs.nonnegative);
            // λ₂ agrees up to the f64→f32 weight rounding
            assert!(
                (vs.second_eig - vd.second_eig).abs() < 1e-6,
                "{scheme:?}: sparse {} vs dense {}",
                vs.second_eig,
                vd.second_eig
            );
        }
    }

    #[test]
    fn power_iteration_matches_jacobi_oracle_to_1e9() {
        // satellite pin: sparse power iteration within 1e-9 of the Jacobi
        // oracle on the same f32-weight matrix, for n up to 200
        let cases = [
            (Topology::Ring, 50),
            (Topology::Ring, 200),
            (Topology::Star, 64),
            (Topology::Torus { rows: 0, cols: 0 }, 100),
            (Topology::ErdosRenyi { p: 0.08 }, 150),
            (Topology::KNearest { k: 3 }, 200),
        ];
        for (ti, (topo, n)) in cases.iter().enumerate() {
            for scheme in [Scheme::Metropolis, Scheme::LazyMetropolis, Scheme::MaxDegree] {
                let g = build_graph(topo, *n, 80 + ti as u64);
                let sp = build_sparse(&g, scheme);
                let power = sp.second_eig_magnitude();
                let jacobi = second_eigenvalue_magnitude(&sp.to_mat());
                assert!(
                    (power - jacobi).abs() < 1e-9,
                    "{topo:?} {scheme:?} n={n}: power {power} vs jacobi {jacobi}"
                );
            }
        }
    }

    #[test]
    fn sparse_roundtrips_to_dense_and_mat() {
        let g = build_graph(&Topology::KNearest { k: 3 }, 20, 3);
        let w = build(&g, Scheme::Metropolis);
        let sp = build_sparse(&g, Scheme::Metropolis);
        assert_eq!(sp.to_dense(), to_f32(&w));
        let m = sp.to_mat();
        for i in 0..20 {
            for j in 0..20 {
                assert_eq!(m[(i, j)], to_f32(&w)[i * 20 + j] as f64);
            }
        }
    }

    #[test]
    fn validate_level_parse() {
        assert_eq!(ValidateLevel::parse("full").unwrap(), ValidateLevel::Full);
        assert_eq!(ValidateLevel::parse("approx").unwrap(), ValidateLevel::Approx);
        assert_eq!(ValidateLevel::parse("skip").unwrap(), ValidateLevel::Skip);
        assert!(ValidateLevel::parse("fast").is_err());
    }

    #[test]
    fn validate_levels_agree_on_structure_and_gap() {
        let g = build_graph(&Topology::KNearest { k: 4 }, 60, 11);
        let sp = build_sparse(&g, Scheme::Metropolis);
        let full = validate_sparse_with(&sp, ValidateLevel::Full);
        let approx = validate_sparse_with(&sp, ValidateLevel::Approx);
        let skip = validate_sparse_with(&sp, ValidateLevel::Skip);
        for v in [&full, &approx, &skip] {
            assert!(v.symmetric && v.rows_stochastic && v.nonnegative);
            assert!(v.holds(), "{v:?}");
        }
        assert!(full.spectral_checked && approx.spectral_checked);
        assert!((full.second_eig - approx.second_eig).abs() < 1e-3);
        // skip never touches the spectrum — NaN sentinel, holds() ungated
        assert!(!skip.spectral_checked);
        assert!(skip.second_eig.is_nan() && skip.spectral_gap.is_nan());
    }

    #[test]
    fn structural_checks_run_at_every_level() {
        // an asymmetric matrix must fail even when the spectrum is skipped
        let bad = SparseW::from_dense(
            2,
            &[0.5, 0.5, /* row 1 breaks symmetry: */ 0.25, 0.75],
        );
        for level in [ValidateLevel::Full, ValidateLevel::Approx, ValidateLevel::Skip] {
            let v = validate_sparse_with(&bad, level);
            assert!(!v.symmetric, "{level:?}");
            assert!(!v.holds(), "{level:?}");
        }
    }

    #[test]
    fn validate_sparse_is_full_level() {
        let g = build_graph(&Topology::Ring, 12, 0);
        let sp = build_sparse(&g, Scheme::Metropolis);
        let a = validate_sparse(&sp);
        let b = validate_sparse_with(&sp, ValidateLevel::Full);
        assert_eq!(a.second_eig.to_bits(), b.second_eig.to_bits());
        assert!(a.spectral_checked);
    }

    #[test]
    fn copy_from_reuses_capacity() {
        let g = build_graph(&Topology::Ring, 10, 0);
        let src = build_sparse(&g, Scheme::Metropolis);
        let mut dst = SparseW::empty();
        dst.reserve_rows_nnz(src.n(), src.nnz());
        dst.copy_from(&src);
        assert_eq!(dst, src);
    }
}
