//! Fruchterman–Reingold force-directed layout (Fig. 1L regeneration).
//!
//! Produces 2-d coordinates for the hospital graph that the experiment
//! harness dumps alongside the DOT export so the paper's left figure can be
//! re-plotted from the JSON output.

use super::Graph;
use crate::rng::Pcg64;

/// 2-d node positions in [0, 1]^2.
pub fn layout(g: &Graph, rng: &mut Pcg64, iterations: usize) -> Vec<(f64, f64)> {
    let n = g.n();
    if n == 0 {
        return Vec::new();
    }
    if n == 1 {
        return vec![(0.5, 0.5)];
    }
    let mut pos: Vec<(f64, f64)> = (0..n).map(|_| (rng.next_f64(), rng.next_f64())).collect();
    let k = (1.0 / n as f64).sqrt(); // ideal edge length
    let mut temp = 0.1;
    let cool = 0.95;

    for _ in 0..iterations {
        let mut disp = vec![(0.0f64, 0.0f64); n];
        // repulsive forces between all pairs
        for i in 0..n {
            for j in (i + 1)..n {
                let dx = pos[i].0 - pos[j].0;
                let dy = pos[i].1 - pos[j].1;
                let d = (dx * dx + dy * dy).sqrt().max(1e-9);
                let f = k * k / d;
                let (ux, uy) = (dx / d, dy / d);
                disp[i].0 += ux * f;
                disp[i].1 += uy * f;
                disp[j].0 -= ux * f;
                disp[j].1 -= uy * f;
            }
        }
        // attractive forces along edges
        for (i, j) in g.edges() {
            let dx = pos[i].0 - pos[j].0;
            let dy = pos[i].1 - pos[j].1;
            let d = (dx * dx + dy * dy).sqrt().max(1e-9);
            let f = d * d / k;
            let (ux, uy) = (dx / d, dy / d);
            disp[i].0 -= ux * f;
            disp[i].1 -= uy * f;
            disp[j].0 += ux * f;
            disp[j].1 += uy * f;
        }
        // displace, capped by temperature
        for i in 0..n {
            let (dx, dy) = disp[i];
            let d = (dx * dx + dy * dy).sqrt().max(1e-9);
            let step = d.min(temp);
            pos[i].0 = (pos[i].0 + dx / d * step).clamp(0.0, 1.0);
            pos[i].1 = (pos[i].1 + dy / d * step).clamp(0.0, 1.0);
        }
        temp *= cool;
    }
    pos
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;

    #[test]
    fn layout_in_unit_square() {
        let mut rng = Pcg64::seed(0);
        let g = Graph::build(&Topology::RandomGeometric { radius: 0.3 }, 20, &mut rng).unwrap();
        let pos = layout(&g, &mut rng, 100);
        assert_eq!(pos.len(), 20);
        for (x, y) in pos {
            assert!((0.0..=1.0).contains(&x) && (0.0..=1.0).contains(&y));
        }
    }

    #[test]
    fn layout_separates_nodes() {
        let mut rng = Pcg64::seed(1);
        let g = Graph::build(&Topology::Ring, 10, &mut rng).unwrap();
        let pos = layout(&g, &mut rng, 200);
        // no two nodes collapsed onto each other
        for i in 0..10 {
            for j in (i + 1)..10 {
                let d = ((pos[i].0 - pos[j].0).powi(2) + (pos[i].1 - pos[j].1).powi(2)).sqrt();
                assert!(d > 1e-3, "nodes {i},{j} collapsed (d={d})");
            }
        }
    }

    #[test]
    fn layout_deterministic_given_seed() {
        let g = Graph::build(&Topology::Ring, 8, &mut Pcg64::seed(2)).unwrap();
        let a = layout(&g, &mut Pcg64::seed(3), 50);
        let b = layout(&g, &mut Pcg64::seed(3), 50);
        assert_eq!(a, b);
    }

    #[test]
    fn singleton_and_empty() {
        let g1 = Graph::empty(1);
        assert_eq!(layout(&g1, &mut Pcg64::seed(0), 10), vec![(0.5, 0.5)]);
        let g0 = Graph::empty(0);
        assert!(layout(&g0, &mut Pcg64::seed(0), 10).is_empty());
    }
}
