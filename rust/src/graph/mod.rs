//! Hospital-network graphs: generators, connectivity, spectra, layout.
//!
//! The paper's setting is an undirected, connected graph of 20 hospitals
//! (Fig. 1 left).  This module provides the topology generators used across
//! the experiments (the paper's RGG-looking network plus the standard
//! ablation families), connectivity validation (Assumption 1 requires a
//! connected graph), spectral statistics, a force-directed layout +
//! DOT export for regenerating Fig. 1L, and the time-varying network
//! schedule (`schedule`) that yields a per-round `(graph, W)` view.

pub mod layout;
pub mod schedule;

pub use schedule::{NetPlan, NetView, NetworkSchedule, ViewScratch};

use crate::linalg::Mat;
use crate::rng::Pcg64;
use anyhow::{bail, Result};

/// Size gate for the dense / quadratic debugging helpers
/// ([`Graph::diameter`], [`Graph::adjacency`], [`Graph::to_dot`], dense
/// mixing matrices).  Past this node count they would silently dominate
/// runtime or memory, so they refuse loudly instead — the sparse-native
/// stack (`mixing::build_sparse`, `NetworkSchedule::view_into`, power
/// iteration) is the only path that scales beyond it.
pub const SMALL_N_LIMIT: usize = 4096;

/// Disjoint-set union (union by size, path halving) with a live component
/// counter.  `reset` re-initializes in O(n) without allocating once the
/// buffers have grown, so generator resample loops and per-round schedule
/// retries can test connectivity incrementally instead of re-running a
/// whole-graph BFS per try.
#[derive(Clone, Debug)]
pub struct UnionFind {
    parent: Vec<u32>,
    size: Vec<u32>,
    comps: usize,
}

impl UnionFind {
    /// Fresh forest of `n` singleton components.
    pub fn new(n: usize) -> Self {
        let mut uf = UnionFind { parent: Vec::new(), size: Vec::new(), comps: 0 };
        uf.reset(n);
        uf
    }

    /// Re-initialize to `n` singletons, reusing the existing buffers.
    pub fn reset(&mut self, n: usize) {
        assert!(n <= u32::MAX as usize, "UnionFind indexes nodes with u32");
        self.parent.clear();
        self.parent.extend(0..n as u32);
        self.size.clear();
        self.size.resize(n, 1);
        self.comps = n;
    }

    /// Representative of `x`'s component (halves paths as it walks).
    pub fn find(&mut self, mut x: usize) -> usize {
        loop {
            let p = self.parent[x] as usize;
            if p == x {
                return x;
            }
            let gp = self.parent[p];
            self.parent[x] = gp;
            x = gp as usize;
        }
    }

    /// Merge the components of `a` and `b`; returns false if already merged.
    pub fn union(&mut self, a: usize, b: usize) -> bool {
        let (ra, rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        let (big, small) = if self.size[ra] >= self.size[rb] { (ra, rb) } else { (rb, ra) };
        self.parent[small] = big as u32;
        self.size[big] += self.size[small];
        self.comps -= 1;
        true
    }

    /// Are `a` and `b` in the same component?
    pub fn connected(&mut self, a: usize, b: usize) -> bool {
        self.find(a) == self.find(b)
    }

    /// Live component count (n minus successful unions).
    pub fn components(&self) -> usize {
        self.comps
    }
}

/// An undirected simple graph over nodes `0..n`.
#[derive(Clone, Debug)]
pub struct Graph {
    n: usize,
    /// Sorted neighbor lists.
    adj: Vec<Vec<usize>>,
}

/// Topology families available in configs and CLI.
#[derive(Clone, Debug, PartialEq)]
pub enum Topology {
    /// Cycle over n nodes.
    Ring,
    /// Path (worst-case diameter).
    Path,
    /// 2-d torus `rows x cols` (n = rows * cols).
    Torus { rows: usize, cols: usize },
    /// Every pair connected.
    Complete,
    /// Hub-and-spoke (the *star network* of classic federated learning).
    Star,
    /// Erdős–Rényi G(n, p), resampled until connected.
    ErdosRenyi { p: f64 },
    /// Random geometric graph on the unit square, radius grown until
    /// connected — visually matches the paper's Fig. 1L hospital network.
    RandomGeometric { radius: f64 },
    /// Watts–Strogatz small world: ring with k nearest neighbors, rewired
    /// with probability beta.
    SmallWorld { k: usize, beta: f64 },
    /// Geometric k-nearest-neighbor graph: random points on the unit square,
    /// each joined to its k nearest; components stitched by their closest
    /// inter-component pair.  Sparse (mean degree ≈ k..2k) and connected —
    /// the closest match to the paper's Fig. 1L hospital network.
    KNearest { k: usize },
}

impl Topology {
    /// Does this family consume randomness when built?  Deterministic
    /// families (ring, path, torus, complete, star) rebuild the identical
    /// graph from any rng, so per-epoch resampling cannot change them —
    /// the rewire net plan rejects them loudly.
    pub fn is_randomized(&self) -> bool {
        matches!(
            self,
            Topology::ErdosRenyi { .. }
                | Topology::RandomGeometric { .. }
                | Topology::SmallWorld { .. }
                | Topology::KNearest { .. }
        )
    }

    /// Parse a CLI/TOML topology name into its default-parameter family.
    pub fn parse(name: &str) -> Result<Topology> {
        Ok(match name {
            "ring" => Topology::Ring,
            "path" => Topology::Path,
            "complete" => Topology::Complete,
            "star" => Topology::Star,
            "torus" => Topology::Torus { rows: 0, cols: 0 }, // sized at build
            "er" | "erdos-renyi" => Topology::ErdosRenyi { p: 0.25 },
            "rgg" | "geometric" => Topology::RandomGeometric { radius: 0.25 },
            "smallworld" | "ws" => Topology::SmallWorld { k: 4, beta: 0.2 },
            "knn" | "geo" => Topology::KNearest { k: 3 },
            other => bail!("unknown topology `{other}` (ring|path|torus|complete|star|er|rgg|smallworld|knn)"),
        })
    }
}

impl Graph {
    /// Edgeless graph over `n` nodes.
    pub fn empty(n: usize) -> Self {
        Graph { n, adj: vec![Vec::new(); n] }
    }

    /// Node count.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Sorted neighbor list of node `i`.
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    /// Degree of node `i`.
    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    /// Is `{i, j}` an edge?
    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        self.adj[i].binary_search(&j).is_ok()
    }

    /// Insert the undirected edge `{i, j}` (idempotent; `i != j`).
    pub fn add_edge(&mut self, i: usize, j: usize) {
        assert!(i < self.n && j < self.n && i != j, "bad edge ({i},{j})");
        if let Err(pos) = self.adj[i].binary_search(&j) {
            self.adj[i].insert(pos, j);
        }
        if let Err(pos) = self.adj[j].binary_search(&i) {
            self.adj[j].insert(pos, i);
        }
    }

    /// Undirected edge list with i < j.
    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for i in 0..self.n {
            for &j in &self.adj[i] {
                if i < j {
                    out.push((i, j));
                }
            }
        }
        out
    }

    /// Undirected edge count.
    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(Vec::len).sum::<usize>() / 2
    }

    /// BFS connectivity check (Assumption 1 precondition).
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut queue = std::collections::VecDeque::from([0usize]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for &v in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == self.n
    }

    /// Dense/quadratic helpers exist for small-n debugging and reporting
    /// only; refuse loudly instead of silently burning O(n²)+ at scale.
    fn assert_small_n(&self, what: &str) {
        assert!(
            self.n <= SMALL_N_LIMIT,
            "Graph::{what} is O(n²)+ and gated to n <= {SMALL_N_LIMIT} (got n = {}); \
             it is a small-n debug/reporting helper — use the sparse-native stack at scale",
            self.n
        );
    }

    /// Graph diameter via repeated BFS.  Small-n only (gated): O(n·E).
    pub fn diameter(&self) -> usize {
        self.assert_small_n("diameter");
        let mut best = 0;
        for s in 0..self.n {
            let mut dist = vec![usize::MAX; self.n];
            dist[s] = 0;
            let mut q = std::collections::VecDeque::from([s]);
            while let Some(u) = q.pop_front() {
                for &v in &self.adj[u] {
                    if dist[v] == usize::MAX {
                        dist[v] = dist[u] + 1;
                        q.push_back(v);
                    }
                }
            }
            best = best.max(*dist.iter().filter(|&&d| d != usize::MAX).max().unwrap_or(&0));
        }
        best
    }

    /// 0/1 adjacency matrix.  Small-n only (gated): materializes n×n.
    pub fn adjacency(&self) -> Mat {
        self.assert_small_n("adjacency");
        let mut a = Mat::zeros(self.n, self.n);
        for (i, j) in self.edges() {
            a[(i, j)] = 1.0;
            a[(j, i)] = 1.0;
        }
        a
    }

    /// Build a topology. `rng` is used by the random families; deterministic
    /// families ignore it.
    pub fn build(topo: &Topology, n: usize, rng: &mut Pcg64) -> Result<Graph> {
        if n == 0 {
            bail!("graph needs at least 1 node");
        }
        let g = match topo {
            Topology::Ring => {
                let mut g = Graph::empty(n);
                if n > 1 {
                    for i in 0..n {
                        g.add_edge(i, (i + 1) % n);
                    }
                }
                g
            }
            Topology::Path => {
                let mut g = Graph::empty(n);
                for i in 1..n {
                    g.add_edge(i - 1, i);
                }
                g
            }
            Topology::Complete => {
                let mut g = Graph::empty(n);
                for i in 0..n {
                    for j in (i + 1)..n {
                        g.add_edge(i, j);
                    }
                }
                g
            }
            Topology::Star => {
                let mut g = Graph::empty(n);
                for i in 1..n {
                    g.add_edge(0, i);
                }
                g
            }
            Topology::Torus { rows, cols } => {
                let (r, c) = if *rows * *cols == n {
                    (*rows, *cols)
                } else {
                    best_torus_dims(n)?
                };
                let mut g = Graph::empty(n);
                for i in 0..r {
                    for j in 0..c {
                        let id = i * c + j;
                        if c > 1 {
                            g.add_edge(id, i * c + (j + 1) % c);
                        }
                        if r > 1 {
                            g.add_edge(id, ((i + 1) % r) * c + j);
                        }
                    }
                }
                g
            }
            Topology::ErdosRenyi { p } => {
                // resample until connected (expected O(1) tries above the
                // threshold); union-find tracks connectivity as edges land,
                // so each failed try costs O(E α(n)) instead of a BFS pass
                let mut uf = UnionFind::new(n);
                for _ in 0..1000 {
                    let mut g = Graph::empty(n);
                    uf.reset(n);
                    for i in 0..n {
                        for j in (i + 1)..n {
                            if rng.bernoulli(*p) {
                                g.add_edge(i, j);
                                uf.union(i, j);
                            }
                        }
                    }
                    if uf.components() == 1 {
                        return Ok(g);
                    }
                }
                bail!("ErdosRenyi(p={p}) failed to produce a connected graph in 1000 tries");
            }
            Topology::RandomGeometric { radius } => {
                let pts: Vec<(f64, f64)> =
                    (0..n).map(|_| (rng.next_f64(), rng.next_f64())).collect();
                // grow radius until connected — incrementally: each pass adds
                // only the edges in the new annulus (prev_r, r] to the same
                // graph and union-find, so the accumulated edge set equals a
                // fresh rebuild at radius r without per-try rebuild + BFS
                let mut g = Graph::empty(n);
                let mut uf = UnionFind::new(n);
                let mut r = *radius;
                let mut prev_r = f64::NEG_INFINITY;
                loop {
                    for i in 0..n {
                        for j in (i + 1)..n {
                            let dx = pts[i].0 - pts[j].0;
                            let dy = pts[i].1 - pts[j].1;
                            let d = (dx * dx + dy * dy).sqrt();
                            if d <= r && d > prev_r {
                                g.add_edge(i, j);
                                uf.union(i, j);
                            }
                        }
                    }
                    if uf.components() == 1 {
                        return Ok(g);
                    }
                    prev_r = r;
                    r *= 1.2;
                    if r > 2.0 {
                        bail!("RGG failed to connect");
                    }
                }
            }
            Topology::SmallWorld { k, beta } => {
                let k = (*k).max(2) & !1usize; // even, >= 2
                if k >= n {
                    bail!("smallworld k={k} >= n={n}");
                }
                let mut g = Graph::empty(n);
                for i in 0..n {
                    for off in 1..=(k / 2) {
                        g.add_edge(i, (i + off) % n);
                    }
                }
                // rewire each ring edge with prob beta
                for i in 0..n {
                    for off in 1..=(k / 2) {
                        let j = (i + off) % n;
                        if rng.bernoulli(*beta) && g.degree(i) > 1 {
                            // pick a new endpoint not already adjacent
                            for _try in 0..16 {
                                let t = rng.range(0, n);
                                if t != i && !g.has_edge(i, t) {
                                    g.remove_edge(i, j);
                                    g.add_edge(i, t);
                                    break;
                                }
                            }
                        }
                    }
                }
                if !g.is_connected() {
                    // fall back: stitch with a ring to guarantee Assumption 1
                    for i in 0..n {
                        g.add_edge(i, (i + 1) % n);
                    }
                }
                g
            }
            Topology::KNearest { k } => {
                let k = (*k).max(1).min(n.saturating_sub(1)).max(1);
                let pts: Vec<(f64, f64)> =
                    (0..n).map(|_| (rng.next_f64(), rng.next_f64())).collect();
                // both paths are exact and select the same neighbors (the
                // grid path's (d², j) order matches the stable sort's
                // ascending-j tie-break), so the switch is invisible; the
                // exact path is kept verbatim as the small-n oracle
                if n <= KNN_GRID_THRESHOLD {
                    build_knn_sort(&pts, k)
                } else {
                    build_knn_grid(&pts, k)
                }
            }
        };
        Ok(g)
    }

    /// Connected-component id per node (BFS labeling).
    pub fn components(&self) -> Vec<usize> {
        let mut comp = vec![usize::MAX; self.n];
        let mut next = 0;
        for s in 0..self.n {
            if comp[s] != usize::MAX {
                continue;
            }
            let mut q = std::collections::VecDeque::from([s]);
            comp[s] = next;
            while let Some(u) = q.pop_front() {
                for &v in &self.adj[u] {
                    if comp[v] == usize::MAX {
                        comp[v] = next;
                        q.push_back(v);
                    }
                }
            }
            next += 1;
        }
        comp
    }

    fn remove_edge(&mut self, i: usize, j: usize) {
        if let Ok(pos) = self.adj[i].binary_search(&j) {
            self.adj[i].remove(pos);
        }
        if let Ok(pos) = self.adj[j].binary_search(&i) {
            self.adj[j].remove(pos);
        }
    }

    /// Graphviz DOT export (Fig. 1L artifact).  Small-n only (gated).
    pub fn to_dot(&self, labels: Option<&[String]>) -> String {
        self.assert_small_n("to_dot");
        let mut out = String::from("graph hospitals {\n  node [shape=circle];\n");
        for i in 0..self.n {
            let label = labels.map(|l| l[i].as_str()).unwrap_or("");
            out.push_str(&format!("  n{i} [label=\"{}\"];\n", if label.is_empty() { format!("H{i}") } else { label.to_string() }));
        }
        for (i, j) in self.edges() {
            out.push_str(&format!("  n{i} -- n{j};\n"));
        }
        out.push_str("}\n");
        out
    }
}

/// Most-square factorization of n for the torus.
fn best_torus_dims(n: usize) -> Result<(usize, usize)> {
    let mut best = None;
    let mut r = 1;
    while r * r <= n {
        if n % r == 0 {
            best = Some((r, n / r));
        }
        r += 1;
    }
    match best {
        Some((1, _)) if n > 2 => bail!("torus needs a composite node count, got prime {n}"),
        Some(dims) => Ok(dims),
        None => bail!("torus needs n >= 1"),
    }
}

/// Above this node count the kNN generator switches from the O(n² log n)
/// sort-based construction to the grid-bucketed exact search.  Both are
/// exact; the threshold only bounds where the quadratic path may run.
const KNN_GRID_THRESHOLD: usize = 2048;

/// Sort-based exact kNN + quadratic stitching — the original small-n path,
/// kept verbatim as the oracle the grid path is property-tested against.
fn build_knn_sort(pts: &[(f64, f64)], k: usize) -> Graph {
    let n = pts.len();
    let d2 = |a: usize, b: usize| {
        let dx = pts[a].0 - pts[b].0;
        let dy = pts[a].1 - pts[b].1;
        dx * dx + dy * dy
    };
    let mut g = Graph::empty(n);
    for i in 0..n {
        let mut others: Vec<usize> = (0..n).filter(|&j| j != i).collect();
        others.sort_by(|&a, &b| d2(i, a).partial_cmp(&d2(i, b)).unwrap());
        for &j in others.iter().take(k) {
            g.add_edge(i, j);
        }
    }
    // stitch components via their closest inter-component pair
    while !g.is_connected() && n > 1 {
        let comp = g.components();
        let mut best: Option<(usize, usize, f64)> = None;
        for i in 0..n {
            for j in (i + 1)..n {
                if comp[i] != comp[j] {
                    let d = d2(i, j);
                    if best.map(|(_, _, bd)| d < bd).unwrap_or(true) {
                        best = Some((i, j, d));
                    }
                }
            }
        }
        let (i, j, _) = best.expect("disconnected graph must have a cross pair");
        g.add_edge(i, j);
    }
    g
}

/// Uniform cell grid over the unit square with CSR-style buckets: ~2 points
/// per cell, so an expanding Chebyshev-ring scan visits O(k) candidates per
/// query in expectation.
struct CellGrid {
    /// Cells per side.
    cps: usize,
    /// Cell width (1 / cps).
    cell: f64,
    /// Bucket offsets, length `cps² + 1`.
    start: Vec<u32>,
    /// Node ids grouped by cell.
    items: Vec<u32>,
}

impl CellGrid {
    fn new(pts: &[(f64, f64)]) -> Self {
        let n = pts.len();
        let cps = ((n as f64 / 2.0).sqrt().ceil() as usize).max(1);
        let at = |x: f64| (((x * cps as f64) as usize).min(cps - 1)) as u32;
        let mut start = vec![0u32; cps * cps + 1];
        for &(x, y) in pts {
            start[(at(y) as usize) * cps + at(x) as usize + 1] += 1;
        }
        for c in 1..start.len() {
            start[c] += start[c - 1];
        }
        let mut fill: Vec<u32> = start[..cps * cps].to_vec();
        let mut items = vec![0u32; n];
        for (i, &(x, y)) in pts.iter().enumerate() {
            let c = (at(y) as usize) * cps + at(x) as usize;
            items[fill[c] as usize] = i as u32;
            fill[c] += 1;
        }
        CellGrid { cps, cell: 1.0 / cps as f64, start, items }
    }

    fn cell_of(&self, p: (f64, f64)) -> (usize, usize) {
        let at = |x: f64| ((x * self.cps as f64) as usize).min(self.cps - 1);
        (at(p.0), at(p.1))
    }

    fn bucket(&self, c: usize) -> &[u32] {
        &self.items[self.start[c] as usize..self.start[c + 1] as usize]
    }

    /// Cell indices of the Chebyshev ring at distance `r` around `(cx, cy)`,
    /// clipped to the grid; returns false once the whole ring falls outside
    /// (at which point every larger ring is outside too).
    fn ring_cells(&self, cx: usize, cy: usize, r: usize, out: &mut Vec<u32>) -> bool {
        out.clear();
        let cps = self.cps as i64;
        let (cx, cy, r) = (cx as i64, cy as i64, r as i64);
        if r == 0 {
            out.push((cy * cps + cx) as u32);
            return true;
        }
        for x in (cx - r).max(0)..=(cx + r).min(cps - 1) {
            if cy - r >= 0 {
                out.push(((cy - r) * cps + x) as u32);
            }
            if cy + r < cps {
                out.push(((cy + r) * cps + x) as u32);
            }
        }
        for y in (cy - r + 1).max(0)..=(cy + r - 1).min(cps - 1) {
            if cx - r >= 0 {
                out.push((y * cps + (cx - r)) as u32);
            }
            if cx + r < cps {
                out.push((y * cps + (cx + r)) as u32);
            }
        }
        !out.is_empty()
    }
}

/// Keep the k lexicographically-smallest `(d², j)` candidates, matching the
/// stable sort's tie-break (equal distances resolve to the smaller index).
fn knn_insert_best(best: &mut Vec<(f64, u32)>, k: usize, cand: (f64, u32)) {
    let pos = best
        .iter()
        .position(|&(d, j)| cand.0 < d || (cand.0 == d && cand.1 < j));
    match pos {
        Some(p) => {
            if best.len() == k {
                best.pop();
            }
            best.insert(p, cand);
        }
        None => {
            if best.len() < k {
                best.push(cand);
            }
        }
    }
}

/// Grid-bucketed exact kNN: expanding Chebyshev rings until the k-th best
/// distance is strictly inside the scanned radius.  Selects the identical
/// neighbor set as [`build_knn_sort`] and stitches components through the
/// same closest-cross-pair rule, found per-node on the grid with union-find
/// tracking connectivity — O(n·k) expected instead of O(n² log n).
fn build_knn_grid(pts: &[(f64, f64)], k: usize) -> Graph {
    let n = pts.len();
    let grid = CellGrid::new(pts);
    let mut g = Graph::empty(n);
    let mut uf = UnionFind::new(n);
    let mut best: Vec<(f64, u32)> = Vec::with_capacity(k);
    let mut cells: Vec<u32> = Vec::new();
    for i in 0..n {
        best.clear();
        let (cx, cy) = grid.cell_of(pts[i]);
        let mut r = 0usize;
        loop {
            let any = grid.ring_cells(cx, cy, r, &mut cells);
            for &c in &cells {
                for &ju in grid.bucket(c as usize) {
                    let j = ju as usize;
                    if j == i {
                        continue;
                    }
                    let dx = pts[i].0 - pts[j].0;
                    let dy = pts[i].1 - pts[j].1;
                    knn_insert_best(&mut best, k, (dx * dx + dy * dy, ju));
                }
            }
            // every point not yet scanned sits in a ring >= r+1, hence at
            // least r·cell away; once the k-th candidate is strictly closer
            // than that, no unseen point can enter (or tie into) the top k
            let guard = r as f64 * grid.cell;
            if best.len() == k && best[k - 1].0 < guard * guard {
                break;
            }
            if !any && r > 0 {
                break; // grid exhausted
            }
            r += 1;
        }
        for &(_, j) in &best {
            g.add_edge(i, j as usize);
            uf.union(i, j as usize);
        }
    }
    // stitch components via their closest inter-component pair: the sort
    // path's full scan picks the (d², i, j)-lexicographic minimum, so we
    // reproduce exactly that via per-node grid searches
    while uf.components() > 1 && n > 1 {
        let mut gbest: Option<(f64, usize, usize)> = None;
        for i in 0..n {
            if let Some((d, j)) = nearest_cross_component(&grid, pts, &mut uf, i, &mut cells) {
                let better = match gbest {
                    None => true,
                    Some((bd, bi, bj)) => d < bd || (d == bd && (i, j) < (bi, bj)),
                };
                if better {
                    gbest = Some((d, i, j));
                }
            }
        }
        let (_, i, j) = gbest.expect("disconnected graph must have a cross pair");
        g.add_edge(i, j);
        uf.union(i, j);
    }
    g
}

/// Nearest node to `i` in a different union-find component, by `(d², j)`
/// lexicographic order; expanding-ring search with the same strict guard as
/// the kNN scan.
fn nearest_cross_component(
    grid: &CellGrid,
    pts: &[(f64, f64)],
    uf: &mut UnionFind,
    i: usize,
    cells: &mut Vec<u32>,
) -> Option<(f64, usize)> {
    let ci = uf.find(i);
    let (cx, cy) = grid.cell_of(pts[i]);
    let mut best: Option<(f64, usize)> = None;
    let mut r = 0usize;
    loop {
        let any = grid.ring_cells(cx, cy, r, cells);
        for &c in cells.iter() {
            for &ju in grid.bucket(c as usize) {
                let j = ju as usize;
                if j == i || uf.find(j) == ci {
                    continue;
                }
                let dx = pts[i].0 - pts[j].0;
                let dy = pts[i].1 - pts[j].1;
                let d = dx * dx + dy * dy;
                let better = match best {
                    None => true,
                    Some((bd, bj)) => d < bd || (d == bd && j < bj),
                };
                if better {
                    best = Some((d, j));
                }
            }
        }
        let guard = r as f64 * grid.cell;
        if let Some((bd, _)) = best {
            if bd < guard * guard {
                return best;
            }
        }
        if !any && r > 0 {
            return best;
        }
        r += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;

    fn rng() -> Pcg64 {
        Pcg64::seed(42)
    }

    #[test]
    fn ring_structure() {
        let g = Graph::build(&Topology::Ring, 20, &mut rng()).unwrap();
        assert_eq!(g.edge_count(), 20);
        assert!(g.is_connected());
        assert!((0..20).all(|i| g.degree(i) == 2));
        assert_eq!(g.diameter(), 10);
    }

    #[test]
    fn path_structure() {
        let g = Graph::build(&Topology::Path, 10, &mut rng()).unwrap();
        assert_eq!(g.edge_count(), 9);
        assert_eq!(g.diameter(), 9);
        assert!(g.is_connected());
    }

    #[test]
    fn complete_structure() {
        let g = Graph::build(&Topology::Complete, 8, &mut rng()).unwrap();
        assert_eq!(g.edge_count(), 28);
        assert_eq!(g.diameter(), 1);
    }

    #[test]
    fn star_structure() {
        let g = Graph::build(&Topology::Star, 20, &mut rng()).unwrap();
        assert_eq!(g.degree(0), 19);
        assert!((1..20).all(|i| g.degree(i) == 1));
        assert_eq!(g.diameter(), 2);
    }

    #[test]
    fn torus_structure() {
        let g = Graph::build(&Topology::Torus { rows: 4, cols: 5 }, 20, &mut rng()).unwrap();
        assert!(g.is_connected());
        assert!((0..20).all(|i| g.degree(i) == 4));
        assert_eq!(g.edge_count(), 40);
    }

    #[test]
    fn torus_auto_dims() {
        let g = Graph::build(&Topology::Torus { rows: 0, cols: 0 }, 20, &mut rng()).unwrap();
        assert!(g.is_connected());
    }

    #[test]
    fn torus_prime_rejected() {
        assert!(Graph::build(&Topology::Torus { rows: 0, cols: 0 }, 13, &mut rng()).is_err());
    }

    #[test]
    fn er_connected_by_construction() {
        for seed in 0..5 {
            let mut r = Pcg64::seed(seed);
            let g = Graph::build(&Topology::ErdosRenyi { p: 0.25 }, 20, &mut r).unwrap();
            assert!(g.is_connected());
        }
    }

    #[test]
    fn rgg_connected_and_paper_sized() {
        let g = Graph::build(&Topology::RandomGeometric { radius: 0.3 }, 20, &mut rng()).unwrap();
        assert!(g.is_connected());
        assert_eq!(g.n(), 20);
    }

    #[test]
    fn smallworld_connected() {
        for seed in 0..5 {
            let mut r = Pcg64::seed(seed);
            let g = Graph::build(&Topology::SmallWorld { k: 4, beta: 0.3 }, 20, &mut r).unwrap();
            assert!(g.is_connected());
        }
    }

    #[test]
    fn edges_symmetric_property() {
        testutil::check("adjacency symmetric", 16, 0, |rng| {
            let n = rng.range(2, 30);
            let g = Graph::build(&Topology::ErdosRenyi { p: 0.4 }, n, rng)
                .map_err(|e| e.to_string())?;
            for (i, j) in g.edges() {
                if !g.has_edge(j, i) {
                    return Err(format!("edge ({i},{j}) not symmetric"));
                }
            }
            let a = g.adjacency();
            if !a.is_symmetric(0.0) {
                return Err("adjacency matrix not symmetric".into());
            }
            Ok(())
        });
    }

    #[test]
    fn degree_sum_equals_twice_edges_property() {
        testutil::check("handshake lemma", 16, 1, |rng| {
            let n = rng.range(2, 30);
            let g = Graph::build(&Topology::ErdosRenyi { p: 0.3 }, n, rng)
                .map_err(|e| e.to_string())?;
            let degsum: usize = (0..n).map(|i| g.degree(i)).sum();
            if degsum == 2 * g.edge_count() {
                Ok(())
            } else {
                Err(format!("degsum {degsum} != 2*{}", g.edge_count()))
            }
        });
    }

    #[test]
    fn disconnected_graph_detected() {
        let mut g = Graph::empty(4);
        g.add_edge(0, 1);
        g.add_edge(2, 3);
        assert!(!g.is_connected());
    }

    #[test]
    fn dot_export_contains_all_edges() {
        let g = Graph::build(&Topology::Ring, 5, &mut rng()).unwrap();
        let dot = g.to_dot(None);
        assert!(dot.starts_with("graph hospitals"));
        assert_eq!(dot.matches(" -- ").count(), 5);
    }

    #[test]
    fn knn_sparse_and_connected() {
        for seed in 0..8 {
            let mut r = Pcg64::seed(seed);
            let g = Graph::build(&Topology::KNearest { k: 3 }, 20, &mut r).unwrap();
            assert!(g.is_connected(), "seed {seed}");
            let mean_deg = 2.0 * g.edge_count() as f64 / 20.0;
            assert!((3.0..=6.5).contains(&mean_deg), "seed {seed}: mean degree {mean_deg}");
        }
    }

    #[test]
    fn components_labels_partition() {
        let mut g = Graph::empty(5);
        g.add_edge(0, 1);
        g.add_edge(2, 3);
        let c = g.components();
        assert_eq!(c[0], c[1]);
        assert_eq!(c[2], c[3]);
        assert_ne!(c[0], c[2]);
        assert_ne!(c[4], c[0]);
        assert_ne!(c[4], c[2]);
    }

    #[test]
    fn topology_parse_roundtrip() {
        for name in ["ring", "path", "complete", "star", "torus", "er", "rgg", "smallworld", "knn"] {
            assert!(Topology::parse(name).is_ok(), "{name}");
        }
        assert!(Topology::parse("bogus").is_err());
    }

    #[test]
    fn union_find_counts_components_and_resets() {
        let mut uf = UnionFind::new(5);
        assert_eq!(uf.components(), 5);
        assert!(uf.union(0, 1));
        assert!(uf.union(2, 3));
        assert!(!uf.union(1, 0), "re-union must be a no-op");
        assert_eq!(uf.components(), 3);
        assert!(uf.connected(0, 1));
        assert!(!uf.connected(0, 2));
        uf.reset(4);
        assert_eq!(uf.components(), 4);
        assert!(!uf.connected(0, 1));
    }

    #[test]
    fn union_find_connectivity_matches_bfs_across_generators_and_seeds() {
        // satellite pin: DSU over the edge list agrees with BFS on both the
        // full generated graph and on random edge-dropped subgraphs
        let fams = [
            Topology::Ring,
            Topology::Path,
            Topology::Complete,
            Topology::Star,
            Topology::Torus { rows: 4, cols: 5 },
            Topology::ErdosRenyi { p: 0.25 },
            Topology::RandomGeometric { radius: 0.3 },
            Topology::SmallWorld { k: 4, beta: 0.2 },
            Topology::KNearest { k: 3 },
        ];
        for (ti, topo) in fams.iter().enumerate() {
            for seed in 0..4u64 {
                let mut r = Pcg64::seed(1000 + 10 * ti as u64 + seed);
                let g = Graph::build(topo, 20, &mut r).unwrap();
                let mut uf = UnionFind::new(g.n());
                for (i, j) in g.edges() {
                    uf.union(i, j);
                }
                assert_eq!(uf.components() == 1, g.is_connected(), "{topo:?} seed {seed}");
                // drop ~40% of edges and compare component structure too
                let mut sub = Graph::empty(g.n());
                uf.reset(g.n());
                for (i, j) in g.edges() {
                    if !r.bernoulli(0.4) {
                        sub.add_edge(i, j);
                        uf.union(i, j);
                    }
                }
                let labels = sub.components();
                let n_comp = labels.iter().max().map(|m| m + 1).unwrap_or(0);
                assert_eq!(uf.components(), n_comp, "{topo:?} seed {seed}: subgraph");
                assert_eq!(uf.components() == 1, sub.is_connected(), "{topo:?} seed {seed}");
                for i in 0..g.n() {
                    for j in 0..g.n() {
                        assert_eq!(
                            uf.connected(i, j),
                            labels[i] == labels[j],
                            "{topo:?} seed {seed}: pair ({i},{j})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn grid_knn_matches_sort_knn() {
        // the grid path must produce the identical edge set as the sort
        // oracle, including stitching, at sizes with nontrivial cell layouts
        for (n, k, seed) in [(150usize, 3usize, 1u64), (400, 3, 2), (400, 5, 3), (701, 2, 4)] {
            let mut r = Pcg64::seed(seed);
            let pts: Vec<(f64, f64)> = (0..n).map(|_| (r.next_f64(), r.next_f64())).collect();
            let a = build_knn_sort(&pts, k);
            let b = build_knn_grid(&pts, k);
            assert_eq!(a.edges(), b.edges(), "n={n} k={k} seed={seed}");
            assert!(b.is_connected(), "n={n} k={k} seed={seed}");
        }
    }

    #[test]
    fn large_knn_builds_sparse_and_connected() {
        // exercises the grid path well past the sort threshold
        let n = 3000;
        let mut r = Pcg64::seed(9);
        let g = Graph::build(&Topology::KNearest { k: 3 }, n, &mut r).unwrap();
        let mut uf = UnionFind::new(n);
        for (i, j) in g.edges() {
            uf.union(i, j);
        }
        assert_eq!(uf.components(), 1);
        let mean_deg = 2.0 * g.edge_count() as f64 / n as f64;
        assert!((3.0..=6.5).contains(&mean_deg), "mean degree {mean_deg}");
    }

    #[test]
    #[should_panic(expected = "gated")]
    fn diameter_gated_at_large_n() {
        let _ = Graph::empty(SMALL_N_LIMIT + 1).diameter();
    }

    #[test]
    #[should_panic(expected = "gated")]
    fn adjacency_gated_at_large_n() {
        let _ = Graph::empty(SMALL_N_LIMIT + 1).adjacency();
    }

    #[test]
    #[should_panic(expected = "gated")]
    fn to_dot_gated_at_large_n() {
        let _ = Graph::empty(SMALL_N_LIMIT + 1).to_dot(None);
    }
}
