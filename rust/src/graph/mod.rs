//! Hospital-network graphs: generators, connectivity, spectra, layout.
//!
//! The paper's setting is an undirected, connected graph of 20 hospitals
//! (Fig. 1 left).  This module provides the topology generators used across
//! the experiments (the paper's RGG-looking network plus the standard
//! ablation families), connectivity validation (Assumption 1 requires a
//! connected graph), spectral statistics, a force-directed layout +
//! DOT export for regenerating Fig. 1L, and the time-varying network
//! schedule (`schedule`) that yields a per-round `(graph, W)` view.

pub mod layout;
pub mod schedule;

pub use schedule::{NetPlan, NetView, NetworkSchedule};

use crate::linalg::Mat;
use crate::rng::Pcg64;
use anyhow::{bail, Result};

/// An undirected simple graph over nodes `0..n`.
#[derive(Clone, Debug)]
pub struct Graph {
    n: usize,
    /// Sorted neighbor lists.
    adj: Vec<Vec<usize>>,
}

/// Topology families available in configs and CLI.
#[derive(Clone, Debug, PartialEq)]
pub enum Topology {
    /// Cycle over n nodes.
    Ring,
    /// Path (worst-case diameter).
    Path,
    /// 2-d torus `rows x cols` (n = rows * cols).
    Torus { rows: usize, cols: usize },
    /// Every pair connected.
    Complete,
    /// Hub-and-spoke (the *star network* of classic federated learning).
    Star,
    /// Erdős–Rényi G(n, p), resampled until connected.
    ErdosRenyi { p: f64 },
    /// Random geometric graph on the unit square, radius grown until
    /// connected — visually matches the paper's Fig. 1L hospital network.
    RandomGeometric { radius: f64 },
    /// Watts–Strogatz small world: ring with k nearest neighbors, rewired
    /// with probability beta.
    SmallWorld { k: usize, beta: f64 },
    /// Geometric k-nearest-neighbor graph: random points on the unit square,
    /// each joined to its k nearest; components stitched by their closest
    /// inter-component pair.  Sparse (mean degree ≈ k..2k) and connected —
    /// the closest match to the paper's Fig. 1L hospital network.
    KNearest { k: usize },
}

impl Topology {
    /// Does this family consume randomness when built?  Deterministic
    /// families (ring, path, torus, complete, star) rebuild the identical
    /// graph from any rng, so per-epoch resampling cannot change them —
    /// the rewire net plan rejects them loudly.
    pub fn is_randomized(&self) -> bool {
        matches!(
            self,
            Topology::ErdosRenyi { .. }
                | Topology::RandomGeometric { .. }
                | Topology::SmallWorld { .. }
                | Topology::KNearest { .. }
        )
    }

    /// Parse a CLI/TOML topology name into its default-parameter family.
    pub fn parse(name: &str) -> Result<Topology> {
        Ok(match name {
            "ring" => Topology::Ring,
            "path" => Topology::Path,
            "complete" => Topology::Complete,
            "star" => Topology::Star,
            "torus" => Topology::Torus { rows: 0, cols: 0 }, // sized at build
            "er" | "erdos-renyi" => Topology::ErdosRenyi { p: 0.25 },
            "rgg" | "geometric" => Topology::RandomGeometric { radius: 0.25 },
            "smallworld" | "ws" => Topology::SmallWorld { k: 4, beta: 0.2 },
            "knn" | "geo" => Topology::KNearest { k: 3 },
            other => bail!("unknown topology `{other}` (ring|path|torus|complete|star|er|rgg|smallworld|knn)"),
        })
    }
}

impl Graph {
    /// Edgeless graph over `n` nodes.
    pub fn empty(n: usize) -> Self {
        Graph { n, adj: vec![Vec::new(); n] }
    }

    /// Node count.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Sorted neighbor list of node `i`.
    pub fn neighbors(&self, i: usize) -> &[usize] {
        &self.adj[i]
    }

    /// Degree of node `i`.
    pub fn degree(&self, i: usize) -> usize {
        self.adj[i].len()
    }

    /// Is `{i, j}` an edge?
    pub fn has_edge(&self, i: usize, j: usize) -> bool {
        self.adj[i].binary_search(&j).is_ok()
    }

    /// Insert the undirected edge `{i, j}` (idempotent; `i != j`).
    pub fn add_edge(&mut self, i: usize, j: usize) {
        assert!(i < self.n && j < self.n && i != j, "bad edge ({i},{j})");
        if let Err(pos) = self.adj[i].binary_search(&j) {
            self.adj[i].insert(pos, j);
        }
        if let Err(pos) = self.adj[j].binary_search(&i) {
            self.adj[j].insert(pos, i);
        }
    }

    /// Undirected edge list with i < j.
    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for i in 0..self.n {
            for &j in &self.adj[i] {
                if i < j {
                    out.push((i, j));
                }
            }
        }
        out
    }

    /// Undirected edge count.
    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(Vec::len).sum::<usize>() / 2
    }

    /// BFS connectivity check (Assumption 1 precondition).
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut queue = std::collections::VecDeque::from([0usize]);
        seen[0] = true;
        let mut count = 1;
        while let Some(u) = queue.pop_front() {
            for &v in &self.adj[u] {
                if !seen[v] {
                    seen[v] = true;
                    count += 1;
                    queue.push_back(v);
                }
            }
        }
        count == self.n
    }

    /// Graph diameter via repeated BFS (n is small).
    pub fn diameter(&self) -> usize {
        let mut best = 0;
        for s in 0..self.n {
            let mut dist = vec![usize::MAX; self.n];
            dist[s] = 0;
            let mut q = std::collections::VecDeque::from([s]);
            while let Some(u) = q.pop_front() {
                for &v in &self.adj[u] {
                    if dist[v] == usize::MAX {
                        dist[v] = dist[u] + 1;
                        q.push_back(v);
                    }
                }
            }
            best = best.max(*dist.iter().filter(|&&d| d != usize::MAX).max().unwrap_or(&0));
        }
        best
    }

    /// 0/1 adjacency matrix.
    pub fn adjacency(&self) -> Mat {
        let mut a = Mat::zeros(self.n, self.n);
        for (i, j) in self.edges() {
            a[(i, j)] = 1.0;
            a[(j, i)] = 1.0;
        }
        a
    }

    /// Build a topology. `rng` is used by the random families; deterministic
    /// families ignore it.
    pub fn build(topo: &Topology, n: usize, rng: &mut Pcg64) -> Result<Graph> {
        if n == 0 {
            bail!("graph needs at least 1 node");
        }
        let g = match topo {
            Topology::Ring => {
                let mut g = Graph::empty(n);
                if n > 1 {
                    for i in 0..n {
                        g.add_edge(i, (i + 1) % n);
                    }
                }
                g
            }
            Topology::Path => {
                let mut g = Graph::empty(n);
                for i in 1..n {
                    g.add_edge(i - 1, i);
                }
                g
            }
            Topology::Complete => {
                let mut g = Graph::empty(n);
                for i in 0..n {
                    for j in (i + 1)..n {
                        g.add_edge(i, j);
                    }
                }
                g
            }
            Topology::Star => {
                let mut g = Graph::empty(n);
                for i in 1..n {
                    g.add_edge(0, i);
                }
                g
            }
            Topology::Torus { rows, cols } => {
                let (r, c) = if *rows * *cols == n {
                    (*rows, *cols)
                } else {
                    best_torus_dims(n)?
                };
                let mut g = Graph::empty(n);
                for i in 0..r {
                    for j in 0..c {
                        let id = i * c + j;
                        if c > 1 {
                            g.add_edge(id, i * c + (j + 1) % c);
                        }
                        if r > 1 {
                            g.add_edge(id, ((i + 1) % r) * c + j);
                        }
                    }
                }
                g
            }
            Topology::ErdosRenyi { p } => {
                // resample until connected (expected O(1) tries above the threshold)
                for _ in 0..1000 {
                    let mut g = Graph::empty(n);
                    for i in 0..n {
                        for j in (i + 1)..n {
                            if rng.bernoulli(*p) {
                                g.add_edge(i, j);
                            }
                        }
                    }
                    if g.is_connected() {
                        return Ok(g);
                    }
                }
                bail!("ErdosRenyi(p={p}) failed to produce a connected graph in 1000 tries");
            }
            Topology::RandomGeometric { radius } => {
                let pts: Vec<(f64, f64)> =
                    (0..n).map(|_| (rng.next_f64(), rng.next_f64())).collect();
                let mut r = *radius;
                loop {
                    let mut g = Graph::empty(n);
                    for i in 0..n {
                        for j in (i + 1)..n {
                            let dx = pts[i].0 - pts[j].0;
                            let dy = pts[i].1 - pts[j].1;
                            if (dx * dx + dy * dy).sqrt() <= r {
                                g.add_edge(i, j);
                            }
                        }
                    }
                    if g.is_connected() {
                        return Ok(g);
                    }
                    r *= 1.2; // grow radius until connected
                    if r > 2.0 {
                        bail!("RGG failed to connect");
                    }
                }
            }
            Topology::SmallWorld { k, beta } => {
                let k = (*k).max(2) & !1usize; // even, >= 2
                if k >= n {
                    bail!("smallworld k={k} >= n={n}");
                }
                let mut g = Graph::empty(n);
                for i in 0..n {
                    for off in 1..=(k / 2) {
                        g.add_edge(i, (i + off) % n);
                    }
                }
                // rewire each ring edge with prob beta
                for i in 0..n {
                    for off in 1..=(k / 2) {
                        let j = (i + off) % n;
                        if rng.bernoulli(*beta) && g.degree(i) > 1 {
                            // pick a new endpoint not already adjacent
                            for _try in 0..16 {
                                let t = rng.range(0, n);
                                if t != i && !g.has_edge(i, t) {
                                    g.remove_edge(i, j);
                                    g.add_edge(i, t);
                                    break;
                                }
                            }
                        }
                    }
                }
                if !g.is_connected() {
                    // fall back: stitch with a ring to guarantee Assumption 1
                    for i in 0..n {
                        g.add_edge(i, (i + 1) % n);
                    }
                }
                g
            }
            Topology::KNearest { k } => {
                let k = (*k).max(1).min(n.saturating_sub(1)).max(1);
                let pts: Vec<(f64, f64)> =
                    (0..n).map(|_| (rng.next_f64(), rng.next_f64())).collect();
                let d2 = |a: usize, b: usize| {
                    let dx = pts[a].0 - pts[b].0;
                    let dy = pts[a].1 - pts[b].1;
                    dx * dx + dy * dy
                };
                let mut g = Graph::empty(n);
                for i in 0..n {
                    let mut others: Vec<usize> = (0..n).filter(|&j| j != i).collect();
                    others.sort_by(|&a, &b| d2(i, a).partial_cmp(&d2(i, b)).unwrap());
                    for &j in others.iter().take(k) {
                        g.add_edge(i, j);
                    }
                }
                // stitch components via their closest inter-component pair
                while !g.is_connected() && n > 1 {
                    let comp = g.components();
                    let mut best: Option<(usize, usize, f64)> = None;
                    for i in 0..n {
                        for j in (i + 1)..n {
                            if comp[i] != comp[j] {
                                let d = d2(i, j);
                                if best.map(|(_, _, bd)| d < bd).unwrap_or(true) {
                                    best = Some((i, j, d));
                                }
                            }
                        }
                    }
                    let (i, j, _) = best.expect("disconnected graph must have a cross pair");
                    g.add_edge(i, j);
                }
                g
            }
        };
        Ok(g)
    }

    /// Connected-component id per node (BFS labeling).
    pub fn components(&self) -> Vec<usize> {
        let mut comp = vec![usize::MAX; self.n];
        let mut next = 0;
        for s in 0..self.n {
            if comp[s] != usize::MAX {
                continue;
            }
            let mut q = std::collections::VecDeque::from([s]);
            comp[s] = next;
            while let Some(u) = q.pop_front() {
                for &v in &self.adj[u] {
                    if comp[v] == usize::MAX {
                        comp[v] = next;
                        q.push_back(v);
                    }
                }
            }
            next += 1;
        }
        comp
    }

    fn remove_edge(&mut self, i: usize, j: usize) {
        if let Ok(pos) = self.adj[i].binary_search(&j) {
            self.adj[i].remove(pos);
        }
        if let Ok(pos) = self.adj[j].binary_search(&i) {
            self.adj[j].remove(pos);
        }
    }

    /// Graphviz DOT export (Fig. 1L artifact).
    pub fn to_dot(&self, labels: Option<&[String]>) -> String {
        let mut out = String::from("graph hospitals {\n  node [shape=circle];\n");
        for i in 0..self.n {
            let label = labels.map(|l| l[i].as_str()).unwrap_or("");
            out.push_str(&format!("  n{i} [label=\"{}\"];\n", if label.is_empty() { format!("H{i}") } else { label.to_string() }));
        }
        for (i, j) in self.edges() {
            out.push_str(&format!("  n{i} -- n{j};\n"));
        }
        out.push_str("}\n");
        out
    }
}

/// Most-square factorization of n for the torus.
fn best_torus_dims(n: usize) -> Result<(usize, usize)> {
    let mut best = None;
    let mut r = 1;
    while r * r <= n {
        if n % r == 0 {
            best = Some((r, n / r));
        }
        r += 1;
    }
    match best {
        Some((1, _)) if n > 2 => bail!("torus needs a composite node count, got prime {n}"),
        Some(dims) => Ok(dims),
        None => bail!("torus needs n >= 1"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil;

    fn rng() -> Pcg64 {
        Pcg64::seed(42)
    }

    #[test]
    fn ring_structure() {
        let g = Graph::build(&Topology::Ring, 20, &mut rng()).unwrap();
        assert_eq!(g.edge_count(), 20);
        assert!(g.is_connected());
        assert!((0..20).all(|i| g.degree(i) == 2));
        assert_eq!(g.diameter(), 10);
    }

    #[test]
    fn path_structure() {
        let g = Graph::build(&Topology::Path, 10, &mut rng()).unwrap();
        assert_eq!(g.edge_count(), 9);
        assert_eq!(g.diameter(), 9);
        assert!(g.is_connected());
    }

    #[test]
    fn complete_structure() {
        let g = Graph::build(&Topology::Complete, 8, &mut rng()).unwrap();
        assert_eq!(g.edge_count(), 28);
        assert_eq!(g.diameter(), 1);
    }

    #[test]
    fn star_structure() {
        let g = Graph::build(&Topology::Star, 20, &mut rng()).unwrap();
        assert_eq!(g.degree(0), 19);
        assert!((1..20).all(|i| g.degree(i) == 1));
        assert_eq!(g.diameter(), 2);
    }

    #[test]
    fn torus_structure() {
        let g = Graph::build(&Topology::Torus { rows: 4, cols: 5 }, 20, &mut rng()).unwrap();
        assert!(g.is_connected());
        assert!((0..20).all(|i| g.degree(i) == 4));
        assert_eq!(g.edge_count(), 40);
    }

    #[test]
    fn torus_auto_dims() {
        let g = Graph::build(&Topology::Torus { rows: 0, cols: 0 }, 20, &mut rng()).unwrap();
        assert!(g.is_connected());
    }

    #[test]
    fn torus_prime_rejected() {
        assert!(Graph::build(&Topology::Torus { rows: 0, cols: 0 }, 13, &mut rng()).is_err());
    }

    #[test]
    fn er_connected_by_construction() {
        for seed in 0..5 {
            let mut r = Pcg64::seed(seed);
            let g = Graph::build(&Topology::ErdosRenyi { p: 0.25 }, 20, &mut r).unwrap();
            assert!(g.is_connected());
        }
    }

    #[test]
    fn rgg_connected_and_paper_sized() {
        let g = Graph::build(&Topology::RandomGeometric { radius: 0.3 }, 20, &mut rng()).unwrap();
        assert!(g.is_connected());
        assert_eq!(g.n(), 20);
    }

    #[test]
    fn smallworld_connected() {
        for seed in 0..5 {
            let mut r = Pcg64::seed(seed);
            let g = Graph::build(&Topology::SmallWorld { k: 4, beta: 0.3 }, 20, &mut r).unwrap();
            assert!(g.is_connected());
        }
    }

    #[test]
    fn edges_symmetric_property() {
        testutil::check("adjacency symmetric", 16, 0, |rng| {
            let n = rng.range(2, 30);
            let g = Graph::build(&Topology::ErdosRenyi { p: 0.4 }, n, rng)
                .map_err(|e| e.to_string())?;
            for (i, j) in g.edges() {
                if !g.has_edge(j, i) {
                    return Err(format!("edge ({i},{j}) not symmetric"));
                }
            }
            let a = g.adjacency();
            if !a.is_symmetric(0.0) {
                return Err("adjacency matrix not symmetric".into());
            }
            Ok(())
        });
    }

    #[test]
    fn degree_sum_equals_twice_edges_property() {
        testutil::check("handshake lemma", 16, 1, |rng| {
            let n = rng.range(2, 30);
            let g = Graph::build(&Topology::ErdosRenyi { p: 0.3 }, n, rng)
                .map_err(|e| e.to_string())?;
            let degsum: usize = (0..n).map(|i| g.degree(i)).sum();
            if degsum == 2 * g.edge_count() {
                Ok(())
            } else {
                Err(format!("degsum {degsum} != 2*{}", g.edge_count()))
            }
        });
    }

    #[test]
    fn disconnected_graph_detected() {
        let mut g = Graph::empty(4);
        g.add_edge(0, 1);
        g.add_edge(2, 3);
        assert!(!g.is_connected());
    }

    #[test]
    fn dot_export_contains_all_edges() {
        let g = Graph::build(&Topology::Ring, 5, &mut rng()).unwrap();
        let dot = g.to_dot(None);
        assert!(dot.starts_with("graph hospitals"));
        assert_eq!(dot.matches(" -- ").count(), 5);
    }

    #[test]
    fn knn_sparse_and_connected() {
        for seed in 0..8 {
            let mut r = Pcg64::seed(seed);
            let g = Graph::build(&Topology::KNearest { k: 3 }, 20, &mut r).unwrap();
            assert!(g.is_connected(), "seed {seed}");
            let mean_deg = 2.0 * g.edge_count() as f64 / 20.0;
            assert!((3.0..=6.5).contains(&mean_deg), "seed {seed}: mean degree {mean_deg}");
        }
    }

    #[test]
    fn components_labels_partition() {
        let mut g = Graph::empty(5);
        g.add_edge(0, 1);
        g.add_edge(2, 3);
        let c = g.components();
        assert_eq!(c[0], c[1]);
        assert_eq!(c[2], c[3]);
        assert_ne!(c[0], c[2]);
        assert_ne!(c[4], c[0]);
        assert_ne!(c[4], c[2]);
    }

    #[test]
    fn topology_parse_roundtrip() {
        for name in ["ring", "path", "complete", "star", "torus", "er", "rgg", "smallworld", "knn"] {
            assert!(Topology::parse(name).is_ok(), "{name}");
        }
        assert!(Topology::parse("bogus").is_err());
    }
}
