//! Time-varying hospital networks: the per-round `(graph, W)` schedule.
//!
//! The paper freezes the network after a single Assumption-1 check, but real
//! hospital WANs churn — links flap, sites go offline, overlays get rebuilt.
//! This module turns the network from a constructor argument into a
//! first-class scheduled resource: a [`NetworkSchedule`] yields a
//! deterministic [`NetView`] (gossip graph, mixing matrix, online mask) for
//! every communication round, derived purely from `(seed, round)` so every
//! driver — and every node thread of the actor driver — reconstructs the
//! identical view independently (the §7 determinism contract).
//!
//! Plans:
//!
//! - [`NetPlan::Static`] — today's behavior: every round sees the base
//!   `(graph, W)` (borrowed, zero-copy), bitwise-identical to the
//!   pre-schedule single-graph loop.
//! - [`NetPlan::Rewire`] — resample the topology family every `every`
//!   rounds (epoch 0 keeps the base graph, so short runs match `Static`);
//!   `W` is rebuilt CSR-first with the configured mixing scheme.
//! - [`NetPlan::EdgeDropout`] — every round each base edge drops with
//!   probability `p`; dropped weights are absorbed into both endpoints'
//!   self-weights, which keeps `W` symmetric and doubly stochastic.
//! - [`NetPlan::NodeChurn`] — every round each node goes offline with
//!   probability `p_offline`; offline nodes skip the communication update
//!   (their `W` row collapses to identity) and neighbors renormalize by
//!   absorbing the lost weight into their self-weight.
//!
//! Per-round Assumption 1: random masks are redrawn (bounded, deterministic
//! retry) until the round's *active* subnetwork — kept edges among online
//! nodes — is connected, so [`NetView::validation`] holds for every emitted
//! view; if no admissible mask is found the round falls back to the fully
//! static view, never to a broken one.
//!
//! Sparse-native (§12 in DESIGN.md): the schedule stores `W` in CSR form
//! ([`SparseW`]) and materializes per-round views by editing CSR rows inside
//! a caller-owned [`ViewScratch`] — connectivity retries run on an
//! incremental union-find, absorption walks each row once, and nothing n×n
//! is ever allocated, so a 10⁵-node federation's schedule costs O(E) per
//! round.

use crate::config::ExperimentConfig;
use crate::graph::{Graph, Topology, UnionFind};
use crate::mixing::{self, Scheme, SparseW, Validation};
use crate::rng::Pcg64;
use anyhow::{bail, Result};

/// RNG stream tags (disjoint from the graph/sampler/init/netsim streams).
const STREAM_REWIRE: u64 = 0x52E1_17E0;
const STREAM_DROP: u64 = 0xD809_A7E0;
const STREAM_CHURN: u64 = 0xC407_12E0;
/// Bounded deterministic resampling for the connectivity requirement.
const MAX_TRIES: usize = 64;

/// How the network evolves across communication rounds.
#[derive(Clone, Debug, PartialEq)]
pub enum NetPlan {
    /// Frozen network — every round sees the base `(graph, W)`.
    Static,
    /// Resample the topology `family` every `every` rounds (epoch 0 = base).
    Rewire { every: usize, family: Topology },
    /// Drop each base edge independently with probability `p` per round.
    EdgeDropout { p: f64 },
    /// Take each node offline with probability `p_offline` per round.
    NodeChurn { p_offline: f64 },
}

impl NetPlan {
    /// Short display label (experiment tables, logs).
    pub fn label(&self) -> String {
        match self {
            NetPlan::Static => "static".into(),
            NetPlan::Rewire { every, .. } => format!("rewire@{every}"),
            NetPlan::EdgeDropout { p } => format!("edge-drop {p:.2}"),
            NetPlan::NodeChurn { p_offline } => format!("churn {p_offline:.2}"),
        }
    }
}

/// Parse the network-plan section of a config (shared by
/// `ExperimentConfig::validate` and [`NetworkSchedule::from_config`]).
pub fn plan_from_config(cfg: &ExperimentConfig) -> Result<NetPlan> {
    match cfg.net_plan.as_str() {
        "static" => Ok(NetPlan::Static),
        "rewire" => {
            if cfg.rewire_every == 0 {
                bail!("rewire_every must be >= 1");
            }
            let family = Topology::parse(&cfg.topology)?;
            if !family.is_randomized() {
                bail!(
                    "net plan `rewire` resamples the topology family every epoch, but \
                     `{}` is deterministic — every epoch would rebuild the identical \
                     graph, silently behaving like `static`; pick a randomized family \
                     (er|rgg|smallworld|knn) or use `edge-drop`/`churn`",
                    cfg.topology
                );
            }
            Ok(NetPlan::Rewire { every: cfg.rewire_every, family })
        }
        "edge-drop" | "edgedrop" => {
            if !(0.0..1.0).contains(&cfg.edge_drop) {
                bail!("edge_drop must be in [0, 1), got {}", cfg.edge_drop);
            }
            Ok(NetPlan::EdgeDropout { p: cfg.edge_drop })
        }
        "churn" => {
            if !(0.0..1.0).contains(&cfg.churn) {
                bail!("churn must be in [0, 1), got {}", cfg.churn);
            }
            Ok(NetPlan::NodeChurn { p_offline: cfg.churn })
        }
        other => bail!("unknown net plan `{other}` (static|rewire|edge-drop|churn)"),
    }
}

/// Grow-only workspace for [`NetworkSchedule::view_into`].  Per-round views
/// are materialized into these buffers (CSR rows edited in place, retries on
/// an incremental union-find), so steady-state rounds allocate nothing once
/// the buffers have reached the base network's size: per-round W is always a
/// subset of the base entries (dropped/offline weights move onto diagonals),
/// hence reserving the base nnz bounds every later round.
#[derive(Clone, Debug)]
pub struct ViewScratch {
    /// Resampled topology (rewire epochs only; allocates per epoch).
    graph: Graph,
    /// The round's mixing matrix when it differs from the base.
    w: SparseW,
    /// Participation mask (churn rounds).
    online: Vec<bool>,
    /// Per-directed-adjacency-slot drop marks (edge-drop rounds), parallel
    /// to the base graph's flattened neighbor lists.
    dropped: Vec<bool>,
    /// Prefix offsets of the base graph's neighbor lists into `dropped`.
    adj_off: Vec<usize>,
    /// Incremental connectivity for mask retries.
    dsu: UnionFind,
}

impl ViewScratch {
    /// Empty workspace; buffers grow to the base network's size on first
    /// use and are reused ever after.
    pub fn new() -> Self {
        ViewScratch {
            graph: Graph::empty(0),
            w: SparseW::empty(),
            online: Vec::new(),
            dropped: Vec::new(),
            adj_off: Vec::new(),
            dsu: UnionFind::new(0),
        }
    }

    /// (Re)build the flattened-adjacency offsets for `g` if its shape
    /// changed; no-op (and allocation-free) otherwise.
    fn ensure_adjacency(&mut self, g: &Graph) {
        let n = g.n();
        let total: usize = (0..n).map(|i| g.degree(i)).sum();
        if self.adj_off.len() == n + 1 && self.adj_off[n] == total {
            return;
        }
        self.adj_off.clear();
        self.adj_off.reserve(n + 1);
        let mut acc = 0usize;
        self.adj_off.push(0);
        for i in 0..n {
            acc += g.degree(i);
            self.adj_off.push(acc);
        }
        self.dropped.reserve(total.saturating_sub(self.dropped.len()));
    }
}

impl Default for ViewScratch {
    fn default() -> Self {
        Self::new()
    }
}

/// One round's network: the gossip topology, its CSR mixing matrix, and
/// which nodes participate.  Every field is borrowed — from the schedule's
/// base for static rounds (zero-copy) or from the caller's [`ViewScratch`]
/// for materialized rounds — so reading a view allocates nothing.
///
/// The effective gossip structure lives in `w`: dropped edges and offline
/// neighbors simply have no CSR entry, so [`NetView::sparse_row`] and
/// [`NetView::active_neighbors_into`] read participation straight off the
/// rows.  `graph` is the round's base topology (rewire epochs swap it) and
/// is *not* pruned per round.
pub struct NetView<'a> {
    /// The gossip topology this round's `W` was derived from.
    pub graph: &'a Graph,
    /// Mixing matrix over all n nodes in CSR form, symmetric and doubly
    /// stochastic (offline rows collapse to identity under churn).
    pub w: &'a SparseW,
    /// Per-node participation mask (all `true` except under churn).
    pub online: &'a [bool],
}

impl<'a> NetView<'a> {
    /// Node count.
    pub fn n(&self) -> usize {
        self.w.n()
    }

    /// Is every node participating this round (no churn)?
    pub fn all_online(&self) -> bool {
        self.online.iter().all(|&b| b)
    }

    /// Row-major dense f32 copy of `W` (what the PJRT-style kernels
    /// consume).  Small-n only (gated) — the debug/test conversion.
    pub fn wf(&self) -> Vec<f32> {
        self.w.to_dense()
    }

    /// Node `i`'s degree-sparse gossip row: `(neighbor index, f32 weight)`
    /// slices in ascending index order, keeping exactly the entries that are
    /// nonzero after the f64→f32 conversion — the same entries, in the same
    /// order, that the dense zero-skipping combine visits, so sparse and
    /// dense gossip are bitwise-identical (self weight included;
    /// offline/dropped neighbors have no entry).  Borrowed straight from the
    /// CSR storage: zero-copy, zero-allocation.
    pub fn sparse_row(&self, i: usize) -> (&'a [u32], &'a [f32]) {
        self.w.row(i)
    }

    /// Fill `out` with this round's gossip partners of node `i` — the
    /// surviving off-diagonal entries of its `W` row — empty when `i` itself
    /// is offline.  Caller-provided scratch; no allocation once `out` has
    /// capacity.
    pub fn active_neighbors_into(&self, i: usize, out: &mut Vec<usize>) {
        out.clear();
        if !self.online[i] {
            return;
        }
        let (idx, _) = self.w.row(i);
        out.extend(idx.iter().map(|&j| j as usize).filter(|&j| j != i));
    }

    /// Directed messages per payload kind this round: both directions of
    /// every surviving edge between online endpoints.
    pub fn active_directed_edges(&self) -> u64 {
        let mut count = 0u64;
        for i in 0..self.n() {
            if !self.online[i] {
                continue;
            }
            let (idx, _) = self.w.row(i);
            count += idx.iter().filter(|&&j| j as usize != i).count() as u64;
        }
        count
    }

    /// Assumption-1 check of the round's *effective* mixing: the full `W`
    /// when everyone is online, the online principal submatrix under churn
    /// (offline nodes sit out the round as identity rows by construction).
    /// Test/debug path — allocates.
    pub fn validation(&self) -> Validation {
        if self.all_online() {
            return mixing::validate_sparse(self.w);
        }
        // relabel online nodes densely (order-preserving, so CSR columns
        // stay ascending) and validate the principal submatrix
        let n = self.n();
        let mut relabel = vec![usize::MAX; n];
        let mut k = 0usize;
        for (i, slot) in relabel.iter_mut().enumerate() {
            if self.online[i] {
                *slot = k;
                k += 1;
            }
        }
        let mut sub = SparseW::empty();
        sub.reset(k);
        for i in 0..n {
            if !self.online[i] {
                continue;
            }
            let (idx, val) = self.w.row(i);
            for (&j, &v) in idx.iter().zip(val) {
                if self.online[j as usize] {
                    sub.push_entry(relabel[j as usize] as u32, v);
                }
            }
            sub.seal_row();
        }
        mixing::validate_sparse(&sub)
    }
}

/// Deterministic per-round network schedule over a validated base
/// `(graph, W)`.  Pure function of `(seed, round)`: every caller — the sync
/// driver, each actor node thread, a test — derives the identical view.
#[derive(Clone, Debug)]
pub struct NetworkSchedule {
    graph: Graph,
    w: SparseW,
    plan: NetPlan,
    scheme: Scheme,
    seed: u64,
    all_online: Vec<bool>,
}

impl NetworkSchedule {
    /// Schedule over a validated base `(graph, w)` pair under `plan`;
    /// `scheme` rebuilds W for resampled topologies, `seed` keys every
    /// per-round draw.  Every off-diagonal entry of `w` must sit on a graph
    /// edge (the per-round absorption walks rows and adjacency in lockstep).
    pub fn new(graph: Graph, w: SparseW, plan: NetPlan, scheme: Scheme, seed: u64) -> Result<Self> {
        if w.n() != graph.n() {
            bail!("W is {0}x{0} but the graph has {1} nodes", w.n(), graph.n());
        }
        for i in 0..graph.n() {
            let (idx, _) = w.row(i);
            let nbrs = graph.neighbors(i);
            let mut p = 0usize;
            for &j in idx {
                let j = j as usize;
                if j == i {
                    continue;
                }
                while p < nbrs.len() && nbrs[p] < j {
                    p += 1;
                }
                if p >= nbrs.len() || nbrs[p] != j {
                    bail!("W row {i} has weight on ({i},{j}) but the graph has no such edge");
                }
            }
        }
        if let NetPlan::Rewire { every, .. } = &plan {
            if *every == 0 {
                bail!("rewire cadence must be >= 1");
            }
        }
        if let NetPlan::EdgeDropout { p } = &plan {
            if !(0.0..1.0).contains(p) {
                bail!("edge dropout probability must be in [0, 1), got {p}");
            }
        }
        if let NetPlan::NodeChurn { p_offline } = &plan {
            if !(0.0..1.0).contains(p_offline) {
                bail!("churn probability must be in [0, 1), got {p_offline}");
            }
        }
        let all_online = vec![true; graph.n()];
        Ok(NetworkSchedule { graph, w, plan, scheme, seed, all_online })
    }

    /// Build from a config's `net.*` section over an assembled base network.
    pub fn from_config(cfg: &ExperimentConfig, graph: Graph, w: SparseW) -> Result<Self> {
        let plan = plan_from_config(cfg)?;
        let scheme = Scheme::parse(&cfg.mixing)?;
        NetworkSchedule::new(graph, w, plan, scheme, cfg.seed)
    }

    /// Node count of the base network.
    pub fn n(&self) -> usize {
        self.graph.n()
    }

    /// The configured per-round plan.
    pub fn plan(&self) -> &NetPlan {
        &self.plan
    }

    /// Does every round see the frozen base network?
    pub fn is_static(&self) -> bool {
        self.plan == NetPlan::Static
    }

    /// Base mixing matrix nonzero count — what a caller should reserve for
    /// per-round W copies (every materialized round's nnz is ≤ this, except
    /// rewire epochs which rebuild from a fresh graph).
    pub fn base_nnz(&self) -> usize {
        self.w.nnz()
    }

    /// Cache key for per-round views: rounds with equal keys see the
    /// identical view, so drivers can skip rebuilding `W`.
    pub fn view_key(&self, round: usize) -> u64 {
        match &self.plan {
            NetPlan::Static => 0,
            NetPlan::Rewire { every, .. } => ((round.max(1) - 1) / every) as u64,
            NetPlan::EdgeDropout { .. } | NetPlan::NodeChurn { .. } => round as u64,
        }
    }

    fn base_view(&self) -> NetView<'_> {
        NetView { graph: &self.graph, w: &self.w, online: &self.all_online[..] }
    }

    /// The network of communication round `round` (1-based; round 0 /
    /// initialization always sees the base view).  Deterministic in
    /// `(seed, round)` — no internal state advances; `scratch` is pure
    /// workspace whose prior contents never influence the result.  Static
    /// rounds borrow the base untouched; materialized rounds borrow
    /// `scratch`.
    ///
    /// # Examples
    ///
    /// ```
    /// use decfl::graph::{Graph, NetPlan, NetworkSchedule, Topology, ViewScratch};
    /// use decfl::mixing::{build_sparse, Scheme};
    /// use decfl::rng::Pcg64;
    ///
    /// let g = Graph::build(&Topology::Ring, 6, &mut Pcg64::seed(1)).unwrap();
    /// let w = build_sparse(&g, Scheme::Metropolis);
    /// let sched = NetworkSchedule::new(
    ///     g, w, NetPlan::EdgeDropout { p: 0.3 }, Scheme::Metropolis, 7,
    /// ).unwrap();
    ///
    /// let mut scratch = ViewScratch::new();
    /// let view = sched.view_into(3, &mut scratch).unwrap(); // pure in (seed, round)
    /// assert!(view.validation().holds());                   // per-round Assumption 1
    /// let w3 = view.w.clone();
    /// let mut other = ViewScratch::new();                   // any caller re-derives it
    /// assert_eq!(&w3, sched.view_into(3, &mut other).unwrap().w);
    /// ```
    pub fn view_into<'s>(
        &'s self,
        round: usize,
        scratch: &'s mut ViewScratch,
    ) -> Result<NetView<'s>> {
        let n = self.graph.n();
        match &self.plan {
            NetPlan::Static => Ok(self.base_view()),
            NetPlan::Rewire { every, family } => {
                let epoch = (round.max(1) - 1) / every;
                if epoch == 0 {
                    return Ok(self.base_view());
                }
                let mut rng = Pcg64::new(self.seed, STREAM_REWIRE + epoch as u64);
                scratch.graph = Graph::build(family, n, &mut rng)?;
                mixing::build_sparse_into(&scratch.graph, self.scheme, &mut scratch.w);
                Ok(NetView {
                    graph: &scratch.graph,
                    w: &scratch.w,
                    online: &self.all_online[..],
                })
            }
            NetPlan::EdgeDropout { p } => {
                let mut rng = Pcg64::new(self.seed, STREAM_DROP + round as u64);
                scratch.ensure_adjacency(&self.graph);
                for _try in 0..MAX_TRIES {
                    scratch.dsu.reset(n);
                    scratch.dropped.clear();
                    scratch.dropped.resize(scratch.adj_off[n], false);
                    let mut any_dropped = false;
                    // same draw order as the base edge list: i asc, j asc, i < j
                    for i in 0..n {
                        for (pos, &j) in self.graph.neighbors(i).iter().enumerate() {
                            if i >= j {
                                continue;
                            }
                            if rng.bernoulli(*p) {
                                any_dropped = true;
                                scratch.dropped[scratch.adj_off[i] + pos] = true;
                                let rev = self
                                    .graph
                                    .neighbors(j)
                                    .binary_search(&i)
                                    .expect("adjacency is symmetric");
                                scratch.dropped[scratch.adj_off[j] + rev] = true;
                            } else {
                                scratch.dsu.union(i, j);
                            }
                        }
                    }
                    if !any_dropped {
                        return Ok(self.base_view());
                    }
                    if scratch.dsu.components() != 1 {
                        continue; // redraw: the round must satisfy Assumption 1
                    }
                    // rebuild W row by row: dropped entries removed, their
                    // weight f64-absorbed into the diagonal (ascending order,
                    // matching the dense absorption's per-row accumulation)
                    scratch.w.reset(n);
                    scratch.w.reserve_rows_nnz(n, self.w.nnz());
                    for i in 0..n {
                        let (bidx, bval) = self.w.row(i);
                        let nbrs = self.graph.neighbors(i);
                        let mut absorbed = 0.0f64;
                        let mut diag = 0.0f64;
                        let mut p_adj = 0usize;
                        for (&j, &v) in bidx.iter().zip(bval) {
                            let j = j as usize;
                            if j == i {
                                diag = v as f64;
                                continue;
                            }
                            while nbrs[p_adj] < j {
                                p_adj += 1;
                            }
                            if scratch.dropped[scratch.adj_off[i] + p_adj] {
                                absorbed += v as f64;
                            }
                        }
                        let new_diag = (diag + absorbed) as f32;
                        let mut p_adj = 0usize;
                        for (&j, &v) in bidx.iter().zip(bval) {
                            let ju = j;
                            let j = j as usize;
                            if j == i {
                                scratch.w.push_entry(ju, new_diag);
                                continue;
                            }
                            while nbrs[p_adj] < j {
                                p_adj += 1;
                            }
                            if !scratch.dropped[scratch.adj_off[i] + p_adj] {
                                scratch.w.push_entry(ju, v);
                            }
                        }
                        scratch.w.seal_row();
                    }
                    return Ok(NetView {
                        graph: &self.graph,
                        w: &scratch.w,
                        online: &self.all_online[..],
                    });
                }
                Ok(self.base_view()) // no connected subgraph found: full round
            }
            NetPlan::NodeChurn { p_offline } => {
                let mut rng = Pcg64::new(self.seed, STREAM_CHURN + round as u64);
                for _try in 0..MAX_TRIES {
                    scratch.online.clear();
                    scratch.online.extend((0..n).map(|_| !rng.bernoulli(*p_offline)));
                    let n_online = scratch.online.iter().filter(|&&b| b).count();
                    if n_online == n {
                        return Ok(self.base_view());
                    }
                    if n_online < 2 {
                        continue; // redraw: online subnetwork must be connected
                    }
                    scratch.dsu.reset(n);
                    for i in 0..n {
                        if !scratch.online[i] {
                            continue;
                        }
                        for &j in self.graph.neighbors(i) {
                            if i < j && scratch.online[j] {
                                scratch.dsu.union(i, j);
                            }
                        }
                    }
                    // online nodes form one component; offline are singletons
                    if scratch.dsu.components() != n - n_online + 1 {
                        continue;
                    }
                    // rebuild W row by row: offline rows collapse to identity,
                    // online rows drop offline entries and f64-absorb their
                    // weight into the diagonal (ascending order)
                    scratch.w.reset(n);
                    scratch.w.reserve_rows_nnz(n, self.w.nnz());
                    for u in 0..n {
                        if !scratch.online[u] {
                            scratch.w.push_entry(u as u32, 1.0);
                            scratch.w.seal_row();
                            continue;
                        }
                        let (bidx, bval) = self.w.row(u);
                        let mut absorbed = 0.0f64;
                        let mut diag = 0.0f64;
                        for (&j, &v) in bidx.iter().zip(bval) {
                            let j = j as usize;
                            if j == u {
                                diag = v as f64;
                            } else if !scratch.online[j] {
                                absorbed += v as f64;
                            }
                        }
                        let new_diag = (diag + absorbed) as f32;
                        for (&j, &v) in bidx.iter().zip(bval) {
                            let ju = j;
                            let j = j as usize;
                            if j == u {
                                scratch.w.push_entry(ju, new_diag);
                            } else if scratch.online[j] {
                                scratch.w.push_entry(ju, v);
                            }
                        }
                        scratch.w.seal_row();
                    }
                    return Ok(NetView {
                        graph: &self.graph,
                        w: &scratch.w,
                        online: &scratch.online[..],
                    });
                }
                Ok(self.base_view()) // no admissible mask: everyone online
            }
        }
    }

    /// Union of every per-round gossip graph over `rounds` rounds — what the
    /// actor driver wires channels over (a superset of any round's edges).
    /// Static, edge-dropout, and churn rounds gossip only over base edges;
    /// rewire epochs contribute their resampled graphs.
    pub fn union_graph(&self, rounds: usize) -> Result<Graph> {
        match &self.plan {
            NetPlan::Rewire { every, .. } => {
                let mut union = self.graph.clone();
                let mut scratch = ViewScratch::new();
                // one representative round per epoch: views are constant inside
                for round in (1..=rounds).step_by((*every).max(1)) {
                    let v = self.view_into(round, &mut scratch)?;
                    for (i, j) in v.graph.edges() {
                        union.add_edge(i, j);
                    }
                }
                Ok(union)
            }
            _ => Ok(self.graph.clone()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;

    fn base(n: usize, seed: u64, topo: &Topology) -> (Graph, SparseW) {
        let g = Graph::build(topo, n, &mut Pcg64::new(seed, 0x6EA9)).unwrap();
        let w = mixing::build_sparse(&g, Scheme::Metropolis);
        (g, w)
    }

    fn schedule(plan: NetPlan, n: usize, seed: u64) -> NetworkSchedule {
        let (g, w) = base(n, seed, &Topology::ErdosRenyi { p: 0.35 });
        NetworkSchedule::new(g, w, plan, Scheme::Metropolis, seed).unwrap()
    }

    fn plans() -> Vec<NetPlan> {
        vec![
            NetPlan::Static,
            NetPlan::Rewire { every: 3, family: Topology::ErdosRenyi { p: 0.35 } },
            NetPlan::EdgeDropout { p: 0.3 },
            NetPlan::NodeChurn { p_offline: 0.25 },
        ]
    }

    /// The round's surviving gossip edges, read off the CSR off-diagonals.
    fn active_edges(v: &NetView) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for i in 0..v.n() {
            let (idx, _) = v.sparse_row(i);
            for &j in idx {
                let j = j as usize;
                if i < j {
                    out.push((i, j));
                }
            }
        }
        out
    }

    #[test]
    fn static_view_is_the_base_network_every_round() {
        let s = schedule(NetPlan::Static, 12, 7);
        let mut scratch = ViewScratch::new();
        for round in [1usize, 2, 17, 100] {
            let v = s.view_into(round, &mut scratch).unwrap();
            // zero-copy: the static view *is* the base, not a clone of it
            assert!(std::ptr::eq(v.graph, &s.graph));
            assert!(std::ptr::eq(v.w, &s.w));
            assert!(v.all_online());
            assert_eq!(s.view_key(round), 0);
        }
    }

    #[test]
    fn every_emitted_w_satisfies_per_round_assumption_1() {
        for seed in [1u64, 7, 23] {
            for plan in plans() {
                let s = schedule(plan.clone(), 12, seed);
                let mut scratch = ViewScratch::new();
                for round in 1..=12 {
                    let v = s.view_into(round, &mut scratch).unwrap();
                    let val = v.validation();
                    assert!(
                        val.holds(),
                        "{} seed {seed} round {round}: {val:?}",
                        plan.label()
                    );
                    // the full-n W stays symmetric + row-stochastic too
                    let full = mixing::validate_sparse(v.w);
                    assert!(full.symmetric, "{} round {round}", plan.label());
                    assert!(full.rows_stochastic, "{} round {round}", plan.label());
                    assert!(full.nonnegative, "{} round {round}", plan.label());
                }
            }
        }
    }

    #[test]
    fn views_are_deterministic_in_seed_and_round_and_scratch_history() {
        for plan in plans() {
            let s = schedule(plan.clone(), 10, 42);
            let s2 = schedule(plan.clone(), 10, 42);
            // one reused scratch vs a fresh scratch every round: prior
            // contents must never leak into the emitted view
            let mut reused = ViewScratch::new();
            for round in 1..=8 {
                let a = s.view_into(round, &mut reused).unwrap();
                let mut fresh = ViewScratch::new();
                let b = s2.view_into(round, &mut fresh).unwrap();
                assert_eq!(a.graph.edges(), b.graph.edges(), "{}", plan.label());
                assert_eq!(a.w, b.w, "{}", plan.label());
                assert_eq!(a.online, b.online, "{}", plan.label());
            }
        }
    }

    #[test]
    fn rewire_changes_only_at_epoch_boundaries() {
        let s = schedule(
            NetPlan::Rewire { every: 3, family: Topology::ErdosRenyi { p: 0.35 } },
            12,
            7,
        );
        let mut scratch = ViewScratch::new();
        // epoch 0 (rounds 1..=3) is the base graph
        for round in 1..=3 {
            let v = s.view_into(round, &mut scratch).unwrap();
            assert_eq!(v.graph.edges(), s.graph.edges());
        }
        // inside an epoch the view is constant; across epochs it may change
        let e1a = s.view_into(4, &mut scratch).unwrap().graph.edges();
        let e1b = s.view_into(6, &mut scratch).unwrap().graph.edges();
        assert_eq!(e1a, e1b);
        assert_eq!(s.view_key(4), s.view_key(6));
        assert_ne!(s.view_key(3), s.view_key(4));
        let mut any_differs = false;
        for round in 4..=24 {
            if s.view_into(round, &mut scratch).unwrap().graph.edges() != s.graph.edges() {
                any_differs = true;
            }
        }
        assert!(any_differs, "rewire never produced a new topology");
    }

    #[test]
    fn edge_dropout_emits_connected_subgraphs_with_absorbed_weight() {
        let s = schedule(NetPlan::EdgeDropout { p: 0.4 }, 12, 3);
        let base_edges = s.graph.edge_count();
        let base_diag = |i: usize| {
            let (idx, val) = s.w.row(i);
            val[idx.binary_search(&(i as u32)).unwrap()]
        };
        let mut scratch = ViewScratch::new();
        let mut any_dropped = false;
        for round in 1..=10 {
            let v = s.view_into(round, &mut scratch).unwrap();
            let kept = active_edges(&v);
            assert!(kept.len() <= base_edges);
            // the surviving edges form a connected graph over base edges only
            let mut uf = UnionFind::new(v.n());
            for &(i, j) in &kept {
                assert!(s.graph.has_edge(i, j), "round {round}: phantom edge ({i},{j})");
                uf.union(i, j);
            }
            assert_eq!(uf.components(), 1, "round {round}");
            if kept.len() < base_edges {
                any_dropped = true;
                // dropped edges have no entry; the diagonal absorbed the mass
                for (i, j) in s.graph.edges() {
                    if !kept.contains(&(i, j)) {
                        let (idx, val) = v.sparse_row(i);
                        assert!(idx.binary_search(&(j as u32)).is_err(), "round {round}");
                        let diag = val[idx.binary_search(&(i as u32)).unwrap()];
                        assert!(diag > base_diag(i), "round {round} node {i}");
                    }
                }
            }
            assert_eq!(v.active_directed_edges(), 2 * kept.len() as u64);
        }
        assert!(any_dropped, "p=0.4 never dropped an edge in 10 rounds");
    }

    #[test]
    fn churn_collapses_offline_rows_to_identity() {
        let s = schedule(NetPlan::NodeChurn { p_offline: 0.3 }, 12, 5);
        let mut scratch = ViewScratch::new();
        let mut nbrs = Vec::new();
        let mut any_offline = false;
        for round in 1..=12 {
            let v = s.view_into(round, &mut scratch).unwrap();
            for i in 0..v.n() {
                let (idx, val) = v.sparse_row(i);
                if !v.online[i] {
                    any_offline = true;
                    assert_eq!(idx, &[i as u32], "round {round} node {i}");
                    assert_eq!(val, &[1.0f32], "round {round} node {i}");
                    v.active_neighbors_into(i, &mut nbrs);
                    assert!(nbrs.is_empty());
                } else {
                    // online rows never reference an offline neighbor
                    for &j in idx {
                        assert!(v.online[j as usize], "round {round} edge ({i},{j})");
                    }
                    v.active_neighbors_into(i, &mut nbrs);
                    for &j in &nbrs {
                        assert!(v.online[i] && v.online[j]);
                        assert!(s.graph.has_edge(i, j));
                    }
                }
            }
        }
        assert!(any_offline, "p_offline=0.3 never took a node offline in 12 rounds");
    }

    #[test]
    fn union_graph_covers_every_round() {
        for plan in plans() {
            let s = schedule(plan.clone(), 10, 11);
            let union = s.union_graph(20).unwrap();
            let mut scratch = ViewScratch::new();
            for round in 1..=20 {
                let v = s.view_into(round, &mut scratch).unwrap();
                for (i, j) in active_edges(&v) {
                    assert!(
                        union.has_edge(i, j),
                        "{} round {round}: edge ({i},{j}) missing from union",
                        plan.label()
                    );
                }
            }
        }
    }

    #[test]
    fn degenerate_probabilities_fall_back_to_static() {
        let mut scratch = ViewScratch::new();
        let s = schedule(NetPlan::EdgeDropout { p: 0.0 }, 8, 7);
        let v = s.view_into(3, &mut scratch).unwrap();
        assert!(std::ptr::eq(v.w, &s.w));
        let s = schedule(NetPlan::NodeChurn { p_offline: 0.0 }, 8, 7);
        assert!(s.view_into(3, &mut scratch).unwrap().all_online());
        // p ~ 1 never finds an admissible mask → full static round
        let s = schedule(NetPlan::EdgeDropout { p: 0.999 }, 8, 7);
        let v = s.view_into(1, &mut scratch).unwrap();
        assert!(v.validation().holds());
    }

    #[test]
    fn inconsistent_base_w_is_rejected() {
        let (g, _) = base(8, 1, &Topology::Ring);
        // W built over a *different* graph has entries off the ring's edges
        let (g2, w2) = base(8, 2, &Topology::ErdosRenyi { p: 0.5 });
        drop(g2);
        let err = NetworkSchedule::new(g, w2, NetPlan::Static, Scheme::Metropolis, 1);
        assert!(err.is_err());
    }

    #[test]
    fn plan_parsing_from_config() {
        let mut cfg = ExperimentConfig::default();
        cfg.net_plan = "static".into();
        assert_eq!(plan_from_config(&cfg).unwrap(), NetPlan::Static);
        cfg.net_plan = "edge-drop".into();
        cfg.edge_drop = 0.3;
        assert_eq!(plan_from_config(&cfg).unwrap(), NetPlan::EdgeDropout { p: 0.3 });
        cfg.net_plan = "churn".into();
        cfg.churn = 0.2;
        assert_eq!(plan_from_config(&cfg).unwrap(), NetPlan::NodeChurn { p_offline: 0.2 });
        cfg.net_plan = "rewire".into();
        assert!(matches!(plan_from_config(&cfg).unwrap(), NetPlan::Rewire { .. }));
        // rewire over a deterministic family is a silent static no-op: rejected
        cfg.topology = "ring".into();
        let err = plan_from_config(&cfg).unwrap_err();
        assert!(err.to_string().contains("deterministic"), "{err}");
        cfg.topology = "er".into();
        assert!(plan_from_config(&cfg).is_ok());
        cfg.net_plan = "bogus".into();
        assert!(plan_from_config(&cfg).is_err());
        cfg.net_plan = "churn".into();
        cfg.churn = 1.5;
        assert!(plan_from_config(&cfg).is_err());
    }
}
