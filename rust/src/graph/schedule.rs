//! Time-varying hospital networks: the per-round `(graph, W)` schedule.
//!
//! The paper freezes the network after a single Assumption-1 check, but real
//! hospital WANs churn — links flap, sites go offline, overlays get rebuilt.
//! This module turns the network from a constructor argument into a
//! first-class scheduled resource: a [`NetworkSchedule`] yields a
//! deterministic [`NetView`] (gossip graph, mixing matrix, online mask) for
//! every communication round, derived purely from `(seed, round)` so every
//! driver — and every node thread of the actor driver — reconstructs the
//! identical view independently (the §7 determinism contract).
//!
//! Plans:
//!
//! - [`NetPlan::Static`] — today's behavior: every round sees the base
//!   `(graph, W)` (borrowed, zero-copy), bitwise-identical to the
//!   pre-schedule single-graph loop.
//! - [`NetPlan::Rewire`] — resample the topology family every `every`
//!   rounds (epoch 0 keeps the base graph, so short runs match `Static`);
//!   `W` is rebuilt with the configured mixing scheme.
//! - [`NetPlan::EdgeDropout`] — every round each base edge drops with
//!   probability `p`; dropped weights are absorbed into both endpoints'
//!   self-weights, which keeps `W` symmetric and doubly stochastic.
//! - [`NetPlan::NodeChurn`] — every round each node goes offline with
//!   probability `p_offline`; offline nodes skip the communication update
//!   (their `W` row collapses to identity) and neighbors renormalize by
//!   absorbing the lost weight into their self-weight.
//!
//! Per-round Assumption 1: random masks are redrawn (bounded, deterministic
//! retry) until the round's *active* subnetwork — kept edges among online
//! nodes — is connected, so [`NetView::validation`] holds for every emitted
//! view; if no admissible mask is found the round falls back to the fully
//! static view, never to a broken one.

use crate::config::ExperimentConfig;
use crate::graph::{Graph, Topology};
use crate::linalg::Mat;
use crate::mixing::{self, Scheme, Validation};
use crate::rng::Pcg64;
use anyhow::{bail, Result};
use std::borrow::Cow;

/// RNG stream tags (disjoint from the graph/sampler/init/netsim streams).
const STREAM_REWIRE: u64 = 0x52E1_17E0;
const STREAM_DROP: u64 = 0xD809_A7E0;
const STREAM_CHURN: u64 = 0xC407_12E0;
/// Bounded deterministic resampling for the connectivity requirement.
const MAX_TRIES: usize = 64;

/// How the network evolves across communication rounds.
#[derive(Clone, Debug, PartialEq)]
pub enum NetPlan {
    /// Frozen network — every round sees the base `(graph, W)`.
    Static,
    /// Resample the topology `family` every `every` rounds (epoch 0 = base).
    Rewire { every: usize, family: Topology },
    /// Drop each base edge independently with probability `p` per round.
    EdgeDropout { p: f64 },
    /// Take each node offline with probability `p_offline` per round.
    NodeChurn { p_offline: f64 },
}

impl NetPlan {
    /// Short display label (experiment tables, logs).
    pub fn label(&self) -> String {
        match self {
            NetPlan::Static => "static".into(),
            NetPlan::Rewire { every, .. } => format!("rewire@{every}"),
            NetPlan::EdgeDropout { p } => format!("edge-drop {p:.2}"),
            NetPlan::NodeChurn { p_offline } => format!("churn {p_offline:.2}"),
        }
    }
}

/// Parse the network-plan section of a config (shared by
/// `ExperimentConfig::validate` and [`NetworkSchedule::from_config`]).
pub fn plan_from_config(cfg: &ExperimentConfig) -> Result<NetPlan> {
    match cfg.net_plan.as_str() {
        "static" => Ok(NetPlan::Static),
        "rewire" => {
            if cfg.rewire_every == 0 {
                bail!("rewire_every must be >= 1");
            }
            let family = Topology::parse(&cfg.topology)?;
            if !family.is_randomized() {
                bail!(
                    "net plan `rewire` resamples the topology family every epoch, but \
                     `{}` is deterministic — every epoch would rebuild the identical \
                     graph, silently behaving like `static`; pick a randomized family \
                     (er|rgg|smallworld|knn) or use `edge-drop`/`churn`",
                    cfg.topology
                );
            }
            Ok(NetPlan::Rewire { every: cfg.rewire_every, family })
        }
        "edge-drop" | "edgedrop" => {
            if !(0.0..1.0).contains(&cfg.edge_drop) {
                bail!("edge_drop must be in [0, 1), got {}", cfg.edge_drop);
            }
            Ok(NetPlan::EdgeDropout { p: cfg.edge_drop })
        }
        "churn" => {
            if !(0.0..1.0).contains(&cfg.churn) {
                bail!("churn must be in [0, 1), got {}", cfg.churn);
            }
            Ok(NetPlan::NodeChurn { p_offline: cfg.churn })
        }
        other => bail!("unknown net plan `{other}` (static|rewire|edge-drop|churn)"),
    }
}

/// One round's network: the gossip graph, its mixing matrix, and which nodes
/// participate.  Borrows the schedule's base for static rounds (zero-copy);
/// owns resampled structures otherwise.
pub struct NetView<'a> {
    /// The gossip graph of this round.  Under [`NetPlan::EdgeDropout`] this
    /// is the kept subgraph; under [`NetPlan::NodeChurn`] it stays the base
    /// graph and `online` masks participation.
    pub graph: Cow<'a, Graph>,
    /// Mixing matrix over all n nodes, symmetric and doubly stochastic
    /// (offline rows collapse to identity under churn).
    pub w: Cow<'a, Mat>,
    /// Per-node participation mask (all `true` except under churn).
    pub online: Cow<'a, [bool]>,
}

impl NetView<'_> {
    /// Node count.
    pub fn n(&self) -> usize {
        self.graph.n()
    }

    /// Is every node participating this round (no churn)?
    pub fn all_online(&self) -> bool {
        self.online.iter().all(|&b| b)
    }

    /// Row-major f32 copy of `W` (what the compute kernels consume).
    pub fn wf(&self) -> Vec<f32> {
        mixing::to_f32(self.w.as_ref())
    }

    /// Node `i`'s degree-sparse gossip row: `(neighbor index, f32 weight)`
    /// pairs in ascending index order, keeping exactly the entries that are
    /// nonzero *after* the f64→f32 conversion — the same entries, in the
    /// same order, that the dense zero-skipping combine visits, so sparse
    /// and dense gossip are bitwise-identical (self weight included;
    /// offline/dropped neighbors carry weight 0 and are excluded).
    pub fn sparse_row(&self, i: usize) -> (Vec<u32>, Vec<f32>) {
        let w: &Mat = self.w.as_ref();
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (j, &x) in w.row(i).iter().enumerate() {
            let v = x as f32;
            if v != 0.0 {
                idx.push(j as u32);
                val.push(v);
            }
        }
        (idx, val)
    }

    /// This round's gossip partners of node `i`: graph neighbors that are
    /// online — empty when `i` itself is offline.
    pub fn active_neighbors(&self, i: usize) -> Vec<usize> {
        if !self.online[i] {
            return Vec::new();
        }
        self.graph.neighbors(i).iter().copied().filter(|&j| self.online[j]).collect()
    }

    /// Directed messages per payload kind this round: both directions of
    /// every kept edge whose endpoints are both online.
    pub fn active_directed_edges(&self) -> u64 {
        let g: &Graph = self.graph.as_ref();
        let mut count = 0u64;
        for i in 0..g.n() {
            if !self.online[i] {
                continue;
            }
            count += g.neighbors(i).iter().filter(|&&j| self.online[j]).count() as u64;
        }
        count
    }

    /// Assumption-1 check of the round's *effective* mixing: the full `W`
    /// when everyone is online, the online principal submatrix under churn
    /// (offline nodes sit out the round as identity rows by construction).
    pub fn validation(&self) -> Validation {
        if self.all_online() {
            return mixing::validate(self.w.as_ref());
        }
        let w: &Mat = self.w.as_ref();
        let online: Vec<usize> = (0..self.n()).filter(|&i| self.online[i]).collect();
        let k = online.len();
        let mut sub = Mat::zeros(k, k);
        for (a, &i) in online.iter().enumerate() {
            for (b, &j) in online.iter().enumerate() {
                sub[(a, b)] = w[(i, j)];
            }
        }
        mixing::validate(&sub)
    }
}

/// Deterministic per-round network schedule over a validated base
/// `(graph, W)`.  Pure function of `(seed, round)`: every caller — the sync
/// driver, each actor node thread, a test — derives the identical view.
#[derive(Clone, Debug)]
pub struct NetworkSchedule {
    graph: Graph,
    w: Mat,
    plan: NetPlan,
    scheme: Scheme,
    seed: u64,
    all_online: Vec<bool>,
}

impl NetworkSchedule {
    /// Schedule over a validated base `(graph, w)` pair under `plan`;
    /// `scheme` rebuilds W for resampled topologies, `seed` keys every
    /// per-round draw.
    pub fn new(graph: Graph, w: Mat, plan: NetPlan, scheme: Scheme, seed: u64) -> Result<Self> {
        if w.rows != graph.n() || w.cols != graph.n() {
            bail!("W is {}x{} but the graph has {} nodes", w.rows, w.cols, graph.n());
        }
        if let NetPlan::Rewire { every, .. } = &plan {
            if *every == 0 {
                bail!("rewire cadence must be >= 1");
            }
        }
        if let NetPlan::EdgeDropout { p } = &plan {
            if !(0.0..1.0).contains(p) {
                bail!("edge dropout probability must be in [0, 1), got {p}");
            }
        }
        if let NetPlan::NodeChurn { p_offline } = &plan {
            if !(0.0..1.0).contains(p_offline) {
                bail!("churn probability must be in [0, 1), got {p_offline}");
            }
        }
        let all_online = vec![true; graph.n()];
        Ok(NetworkSchedule { graph, w, plan, scheme, seed, all_online })
    }

    /// Build from a config's `net.*` section over an assembled base network.
    pub fn from_config(cfg: &ExperimentConfig, graph: Graph, w: Mat) -> Result<Self> {
        let plan = plan_from_config(cfg)?;
        let scheme = Scheme::parse(&cfg.mixing)?;
        NetworkSchedule::new(graph, w, plan, scheme, cfg.seed)
    }

    /// Node count of the base network.
    pub fn n(&self) -> usize {
        self.graph.n()
    }

    /// The configured per-round plan.
    pub fn plan(&self) -> &NetPlan {
        &self.plan
    }

    /// Does every round see the frozen base network?
    pub fn is_static(&self) -> bool {
        self.plan == NetPlan::Static
    }

    /// Cache key for per-round views: rounds with equal keys see the
    /// identical view, so drivers can skip rebuilding `W`.
    pub fn view_key(&self, round: usize) -> u64 {
        match &self.plan {
            NetPlan::Static => 0,
            NetPlan::Rewire { every, .. } => ((round.max(1) - 1) / every) as u64,
            NetPlan::EdgeDropout { .. } | NetPlan::NodeChurn { .. } => round as u64,
        }
    }

    fn base_view(&self) -> NetView<'_> {
        NetView {
            graph: Cow::Borrowed(&self.graph),
            w: Cow::Borrowed(&self.w),
            online: Cow::Borrowed(&self.all_online[..]),
        }
    }

    /// The network of communication round `round` (1-based; round 0 /
    /// initialization always sees the base view).  Deterministic in
    /// `(seed, round)` — no internal state advances.
    ///
    /// # Examples
    ///
    /// ```
    /// use decfl::graph::{Graph, NetPlan, NetworkSchedule, Topology};
    /// use decfl::mixing::{build, Scheme};
    /// use decfl::rng::Pcg64;
    ///
    /// let g = Graph::build(&Topology::Ring, 6, &mut Pcg64::seed(1)).unwrap();
    /// let w = build(&g, Scheme::Metropolis);
    /// let sched = NetworkSchedule::new(
    ///     g, w, NetPlan::EdgeDropout { p: 0.3 }, Scheme::Metropolis, 7,
    /// ).unwrap();
    ///
    /// let view = sched.view(3).unwrap();       // pure in (seed, round)
    /// assert!(view.validation().holds());      // per-round Assumption 1
    /// let again = sched.view(3).unwrap();      // any caller re-derives it
    /// assert_eq!(view.w.data, again.w.data);
    /// ```
    pub fn view(&self, round: usize) -> Result<NetView<'_>> {
        let n = self.graph.n();
        match &self.plan {
            NetPlan::Static => Ok(self.base_view()),
            NetPlan::Rewire { every, family } => {
                let epoch = (round.max(1) - 1) / every;
                if epoch == 0 {
                    return Ok(self.base_view());
                }
                let mut rng = Pcg64::new(self.seed, STREAM_REWIRE + epoch as u64);
                let g = Graph::build(family, n, &mut rng)?;
                let w = mixing::build(&g, self.scheme);
                Ok(NetView {
                    graph: Cow::Owned(g),
                    w: Cow::Owned(w),
                    online: Cow::Borrowed(&self.all_online[..]),
                })
            }
            NetPlan::EdgeDropout { p } => {
                let mut rng = Pcg64::new(self.seed, STREAM_DROP + round as u64);
                let edges = self.graph.edges();
                for _try in 0..MAX_TRIES {
                    let mut kept = Graph::empty(n);
                    let mut dropped = Vec::new();
                    for &(i, j) in &edges {
                        if rng.bernoulli(*p) {
                            dropped.push((i, j));
                        } else {
                            kept.add_edge(i, j);
                        }
                    }
                    if dropped.is_empty() {
                        return Ok(self.base_view());
                    }
                    if !kept.is_connected() {
                        continue; // redraw: the round must satisfy Assumption 1
                    }
                    let w = absorb_edges(&self.w, &dropped);
                    return Ok(NetView {
                        graph: Cow::Owned(kept),
                        w: Cow::Owned(w),
                        online: Cow::Borrowed(&self.all_online[..]),
                    });
                }
                Ok(self.base_view()) // no connected subgraph found: full round
            }
            NetPlan::NodeChurn { p_offline } => {
                let mut rng = Pcg64::new(self.seed, STREAM_CHURN + round as u64);
                for _try in 0..MAX_TRIES {
                    let online: Vec<bool> = (0..n).map(|_| !rng.bernoulli(*p_offline)).collect();
                    let n_online = online.iter().filter(|&&b| b).count();
                    if n_online == n {
                        return Ok(self.base_view());
                    }
                    if n_online < 2 || !induced_connected(&self.graph, &online) {
                        continue; // redraw: online subnetwork must be connected
                    }
                    let w = absorb_offline(&self.w, &online);
                    return Ok(NetView {
                        graph: Cow::Borrowed(&self.graph),
                        w: Cow::Owned(w),
                        online: Cow::Owned(online),
                    });
                }
                Ok(self.base_view()) // no admissible mask: everyone online
            }
        }
    }

    /// Union of every per-round gossip graph over `rounds` rounds — what the
    /// actor driver wires channels over (a superset of any round's edges).
    /// Static, edge-dropout, and churn rounds gossip only over base edges;
    /// rewire epochs contribute their resampled graphs.
    pub fn union_graph(&self, rounds: usize) -> Result<Graph> {
        match &self.plan {
            NetPlan::Rewire { every, .. } => {
                let mut union = self.graph.clone();
                // one representative round per epoch: views are constant inside
                for round in (1..=rounds).step_by((*every).max(1)) {
                    let v = self.view(round)?;
                    for (i, j) in v.graph.edges() {
                        union.add_edge(i, j);
                    }
                }
                Ok(union)
            }
            _ => Ok(self.graph.clone()),
        }
    }
}

/// Zero the dropped edges of `w` and absorb their weight into both
/// endpoints' self-weights — symmetry and double stochasticity preserved.
fn absorb_edges(w: &Mat, dropped: &[(usize, usize)]) -> Mat {
    let mut out = w.clone();
    for &(i, j) in dropped {
        let wij = out[(i, j)];
        out[(i, i)] += wij;
        out[(j, j)] += wij;
        out[(i, j)] = 0.0;
        out[(j, i)] = 0.0;
    }
    out
}

/// Collapse offline rows/columns of `w` to identity: each online neighbor
/// absorbs the lost weight into its self-weight, and the offline row becomes
/// exactly `e_u` — symmetry and double stochasticity preserved.
fn absorb_offline(w: &Mat, online: &[bool]) -> Mat {
    let n = w.rows;
    let mut out = w.clone();
    for u in 0..n {
        if online[u] {
            continue;
        }
        for v in 0..n {
            if v == u {
                continue;
            }
            let wvu = out[(v, u)];
            if online[v] && wvu != 0.0 {
                out[(v, v)] += wvu;
            }
            out[(v, u)] = 0.0;
            out[(u, v)] = 0.0;
        }
        out[(u, u)] = 1.0;
    }
    out
}

/// Is the subgraph induced by the online nodes connected?
fn induced_connected(g: &Graph, online: &[bool]) -> bool {
    let n = g.n();
    let total = online.iter().filter(|&&b| b).count();
    let Some(start) = (0..n).find(|&i| online[i]) else {
        return false;
    };
    let mut seen = vec![false; n];
    let mut queue = std::collections::VecDeque::from([start]);
    seen[start] = true;
    let mut count = 1;
    while let Some(u) = queue.pop_front() {
        for &v in g.neighbors(u) {
            if online[v] && !seen[v] {
                seen[v] = true;
                count += 1;
                queue.push_back(v);
            }
        }
    }
    count == total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Topology;

    fn base(n: usize, seed: u64, topo: &Topology) -> (Graph, Mat) {
        let g = Graph::build(topo, n, &mut Pcg64::new(seed, 0x6EA9)).unwrap();
        let w = mixing::build(&g, Scheme::Metropolis);
        (g, w)
    }

    fn schedule(plan: NetPlan, n: usize, seed: u64) -> NetworkSchedule {
        let (g, w) = base(n, seed, &Topology::ErdosRenyi { p: 0.35 });
        NetworkSchedule::new(g, w, plan, Scheme::Metropolis, seed).unwrap()
    }

    fn plans() -> Vec<NetPlan> {
        vec![
            NetPlan::Static,
            NetPlan::Rewire { every: 3, family: Topology::ErdosRenyi { p: 0.35 } },
            NetPlan::EdgeDropout { p: 0.3 },
            NetPlan::NodeChurn { p_offline: 0.25 },
        ]
    }

    #[test]
    fn static_view_is_the_base_network_every_round() {
        let s = schedule(NetPlan::Static, 12, 7);
        for round in [1usize, 2, 17, 100] {
            let v = s.view(round).unwrap();
            assert_eq!(v.graph.edges(), s.graph.edges());
            assert_eq!(v.w.data, s.w.data);
            assert!(v.all_online());
            assert_eq!(s.view_key(round), 0);
        }
    }

    #[test]
    fn every_emitted_w_satisfies_per_round_assumption_1() {
        for seed in [1u64, 7, 23] {
            for plan in plans() {
                let s = schedule(plan.clone(), 12, seed);
                for round in 1..=12 {
                    let v = s.view(round).unwrap();
                    let val = v.validation();
                    assert!(
                        val.holds(),
                        "{} seed {seed} round {round}: {val:?}",
                        plan.label()
                    );
                    // the full-n W stays symmetric + doubly stochastic too
                    let w: &Mat = v.w.as_ref();
                    assert!(w.is_symmetric(1e-12), "{} round {round}", plan.label());
                    for i in 0..v.n() {
                        let sum: f64 = w.row(i).iter().sum();
                        assert!(
                            (sum - 1.0).abs() < 1e-9,
                            "{} round {round} row {i} sums to {sum}",
                            plan.label()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn views_are_deterministic_in_seed_and_round() {
        for plan in plans() {
            let s = schedule(plan.clone(), 10, 42);
            let s2 = schedule(plan.clone(), 10, 42);
            for round in 1..=8 {
                let a = s.view(round).unwrap();
                let b = s2.view(round).unwrap();
                assert_eq!(a.graph.edges(), b.graph.edges(), "{}", plan.label());
                assert_eq!(a.w.data, b.w.data, "{}", plan.label());
                assert_eq!(&a.online[..], &b.online[..], "{}", plan.label());
            }
        }
    }

    #[test]
    fn rewire_changes_only_at_epoch_boundaries() {
        let s = schedule(
            NetPlan::Rewire { every: 3, family: Topology::ErdosRenyi { p: 0.35 } },
            12,
            7,
        );
        // epoch 0 (rounds 1..=3) is the base graph
        for round in 1..=3 {
            assert_eq!(s.view(round).unwrap().graph.edges(), s.graph.edges());
        }
        // inside an epoch the view is constant; across epochs it may change
        let e1a = s.view(4).unwrap();
        let e1b = s.view(6).unwrap();
        assert_eq!(e1a.graph.edges(), e1b.graph.edges());
        assert_eq!(s.view_key(4), s.view_key(6));
        assert_ne!(s.view_key(3), s.view_key(4));
        let mut any_differs = false;
        for round in 4..=24 {
            if s.view(round).unwrap().graph.edges() != s.graph.edges() {
                any_differs = true;
            }
        }
        assert!(any_differs, "rewire never produced a new topology");
    }

    #[test]
    fn edge_dropout_emits_connected_subgraphs_with_absorbed_weight() {
        let s = schedule(NetPlan::EdgeDropout { p: 0.4 }, 12, 3);
        let base_edges = s.graph.edge_count();
        let mut any_dropped = false;
        for round in 1..=10 {
            let v = s.view(round).unwrap();
            assert!(v.graph.is_connected(), "round {round}");
            assert!(v.graph.edge_count() <= base_edges);
            // kept subgraph only contains base edges
            for (i, j) in v.graph.edges() {
                assert!(s.graph.has_edge(i, j), "round {round}: phantom edge ({i},{j})");
            }
            if v.graph.edge_count() < base_edges {
                any_dropped = true;
                // dropped edges have zero weight; diagonal absorbed the mass
                let w: &Mat = v.w.as_ref();
                for (i, j) in s.graph.edges() {
                    if !v.graph.has_edge(i, j) {
                        assert_eq!(w[(i, j)], 0.0);
                        assert!(w[(i, i)] > s.w[(i, i)]);
                    }
                }
            }
            assert_eq!(v.active_directed_edges(), 2 * v.graph.edge_count() as u64);
        }
        assert!(any_dropped, "p=0.4 never dropped an edge in 10 rounds");
    }

    #[test]
    fn churn_collapses_offline_rows_to_identity() {
        let s = schedule(NetPlan::NodeChurn { p_offline: 0.3 }, 12, 5);
        let mut any_offline = false;
        for round in 1..=12 {
            let v = s.view(round).unwrap();
            let w: &Mat = v.w.as_ref();
            for i in 0..v.n() {
                if !v.online[i] {
                    any_offline = true;
                    assert_eq!(w[(i, i)], 1.0, "round {round} node {i}");
                    for j in 0..v.n() {
                        if j != i {
                            assert_eq!(w[(i, j)], 0.0);
                            assert_eq!(w[(j, i)], 0.0);
                        }
                    }
                    assert!(v.active_neighbors(i).is_empty());
                }
            }
            // active edges never touch an offline endpoint
            for i in 0..v.n() {
                for j in v.active_neighbors(i) {
                    assert!(v.online[i] && v.online[j]);
                }
            }
        }
        assert!(any_offline, "p_offline=0.3 never took a node offline in 12 rounds");
    }

    #[test]
    fn union_graph_covers_every_round() {
        for plan in plans() {
            let s = schedule(plan.clone(), 10, 11);
            let union = s.union_graph(20).unwrap();
            for round in 1..=20 {
                for (i, j) in s.view(round).unwrap().graph.edges() {
                    assert!(
                        union.has_edge(i, j),
                        "{} round {round}: edge ({i},{j}) missing from union",
                        plan.label()
                    );
                }
            }
        }
    }

    #[test]
    fn degenerate_probabilities_fall_back_to_static() {
        let s = schedule(NetPlan::EdgeDropout { p: 0.0 }, 8, 7);
        let v = s.view(3).unwrap();
        assert_eq!(v.graph.edges(), s.graph.edges());
        let s = schedule(NetPlan::NodeChurn { p_offline: 0.0 }, 8, 7);
        assert!(s.view(3).unwrap().all_online());
        // p ~ 1 never finds an admissible mask → full static round
        let s = schedule(NetPlan::EdgeDropout { p: 0.999 }, 8, 7);
        let v = s.view(1).unwrap();
        assert!(v.graph.is_connected());
        assert!(v.validation().holds());
    }

    #[test]
    fn plan_parsing_from_config() {
        let mut cfg = ExperimentConfig::default();
        cfg.net_plan = "static".into();
        assert_eq!(plan_from_config(&cfg).unwrap(), NetPlan::Static);
        cfg.net_plan = "edge-drop".into();
        cfg.edge_drop = 0.3;
        assert_eq!(plan_from_config(&cfg).unwrap(), NetPlan::EdgeDropout { p: 0.3 });
        cfg.net_plan = "churn".into();
        cfg.churn = 0.2;
        assert_eq!(plan_from_config(&cfg).unwrap(), NetPlan::NodeChurn { p_offline: 0.2 });
        cfg.net_plan = "rewire".into();
        assert!(matches!(plan_from_config(&cfg).unwrap(), NetPlan::Rewire { .. }));
        // rewire over a deterministic family is a silent static no-op: rejected
        cfg.topology = "ring".into();
        let err = plan_from_config(&cfg).unwrap_err();
        assert!(err.to_string().contains("deterministic"), "{err}");
        cfg.topology = "er".into();
        assert!(plan_from_config(&cfg).is_ok());
        cfg.net_plan = "bogus".into();
        assert!(plan_from_config(&cfg).is_err());
        cfg.net_plan = "churn".into();
        cfg.churn = 1.5;
        assert!(plan_from_config(&cfg).is_err());
    }
}
