//! Exact t-SNE (Fig. 1R regeneration) + silhouette score.
//!
//! The paper's Fig. 1 (right) embeds samples from three hospitals with t-SNE
//! and shows well-separated per-hospital clusters — the visual argument for
//! data heterogeneity.  This is an exact O(n²) implementation (van der
//! Maaten & Hinton, 2008): perplexity calibration by per-point binary search
//! over Gaussian bandwidths, early exaggeration, momentum gradient descent.
//! n is a few hundred samples, so quadratic cost is negligible.
//!
//! The silhouette score over hospital identity quantifies the separation so
//! the heterogeneity claim is checkable numerically, not just visually.

use crate::linalg::{dist2, Mat};
use crate::rng::Pcg64;
use anyhow::{bail, Result};

/// t-SNE hyperparameters.
#[derive(Clone, Debug)]
pub struct TsneConfig {
    /// Target perplexity of the conditional distributions.
    pub perplexity: f64,
    /// Gradient-descent iterations.
    pub iterations: usize,
    /// Gradient-descent step size.
    pub learning_rate: f64,
    /// Early-exaggeration factor applied for the first quarter of iterations.
    pub exaggeration: f64,
    /// RNG seed for the initialization jitter.
    pub seed: u64,
}

impl Default for TsneConfig {
    fn default() -> Self {
        TsneConfig {
            perplexity: 30.0,
            iterations: 500,
            learning_rate: 100.0,
            exaggeration: 12.0,
            seed: 0,
        }
    }
}

/// Embed rows of `x` (n x d) into 2-d.
pub fn tsne(x: &Mat, cfg: &TsneConfig) -> Result<Mat> {
    let n = x.rows;
    if n < 5 {
        bail!("t-SNE needs at least 5 points, got {n}");
    }
    if cfg.perplexity >= n as f64 {
        bail!("perplexity {} must be < n = {n}", cfg.perplexity);
    }

    // pairwise squared distances in input space
    let mut d2 = vec![0.0f64; n * n];
    for i in 0..n {
        for j in (i + 1)..n {
            let v = dist2(x.row(i), x.row(j));
            d2[i * n + j] = v;
            d2[j * n + i] = v;
        }
    }

    // per-point bandwidths by binary search on perplexity
    let target_entropy = cfg.perplexity.ln();
    let mut p = vec![0.0f64; n * n];
    for i in 0..n {
        let row = &d2[i * n..(i + 1) * n];
        let mut beta = 1.0; // 1 / (2 sigma^2)
        let (mut lo, mut hi) = (0.0f64, f64::INFINITY);
        let mut probs = vec![0.0f64; n];
        for _ in 0..64 {
            let mut sum = 0.0;
            for j in 0..n {
                probs[j] = if j == i { 0.0 } else { (-beta * row[j]).exp() };
                sum += probs[j];
            }
            if sum <= 0.0 {
                beta *= 0.5;
                continue;
            }
            // entropy H = ln(sum) + beta * <d2>
            let mut h = 0.0;
            for j in 0..n {
                if probs[j] > 0.0 {
                    h += beta * row[j] * probs[j];
                }
            }
            let entropy = sum.ln() + h / sum;
            let diff = entropy - target_entropy;
            if diff.abs() < 1e-5 {
                break;
            }
            if diff > 0.0 {
                lo = beta;
                beta = if hi.is_finite() { (beta + hi) / 2.0 } else { beta * 2.0 };
            } else {
                hi = beta;
                beta = (beta + lo) / 2.0;
            }
        }
        let sum: f64 = probs.iter().sum::<f64>().max(1e-300);
        for j in 0..n {
            p[i * n + j] = probs[j] / sum;
        }
    }

    // symmetrize
    let mut pij = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            pij[i * n + j] = ((p[i * n + j] + p[j * n + i]) / (2.0 * n as f64)).max(1e-12);
        }
    }

    // init embedding from small Gaussian noise
    let mut rng = Pcg64::seed(cfg.seed);
    let mut y: Vec<(f64, f64)> = (0..n).map(|_| (rng.normal() * 1e-2, rng.normal() * 1e-2)).collect();
    let mut vel = vec![(0.0f64, 0.0f64); n];

    let exag_end = cfg.iterations / 4;
    for it in 0..cfg.iterations {
        let exag = if it < exag_end { cfg.exaggeration } else { 1.0 };
        let momentum = if it < exag_end { 0.5 } else { 0.8 };

        // student-t affinities in embedding space
        let mut qnum = vec![0.0f64; n * n];
        let mut qsum = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                let dx = y[i].0 - y[j].0;
                let dy = y[i].1 - y[j].1;
                let q = 1.0 / (1.0 + dx * dx + dy * dy);
                qnum[i * n + j] = q;
                qnum[j * n + i] = q;
                qsum += 2.0 * q;
            }
        }
        let qsum = qsum.max(1e-300);

        // gradient
        for i in 0..n {
            let mut gx = 0.0;
            let mut gy = 0.0;
            for j in 0..n {
                if i == j {
                    continue;
                }
                let qn = qnum[i * n + j];
                let qij = (qn / qsum).max(1e-12);
                let coeff = 4.0 * (exag * pij[i * n + j] - qij) * qn;
                gx += coeff * (y[i].0 - y[j].0);
                gy += coeff * (y[i].1 - y[j].1);
            }
            vel[i].0 = momentum * vel[i].0 - cfg.learning_rate * gx;
            vel[i].1 = momentum * vel[i].1 - cfg.learning_rate * gy;
        }
        for i in 0..n {
            y[i].0 += vel[i].0;
            y[i].1 += vel[i].1;
        }

        // recenter
        let cx = y.iter().map(|p| p.0).sum::<f64>() / n as f64;
        let cy = y.iter().map(|p| p.1).sum::<f64>() / n as f64;
        for pt in &mut y {
            pt.0 -= cx;
            pt.1 -= cy;
        }
    }

    let mut out = Mat::zeros(n, 2);
    for i in 0..n {
        out[(i, 0)] = y[i].0;
        out[(i, 1)] = y[i].1;
    }
    Ok(out)
}

/// Mean silhouette coefficient of a labeled embedding (label = hospital id).
/// +1 = perfectly separated clusters, 0 = overlapping, < 0 = mixed.
pub fn silhouette(points: &Mat, labels: &[usize]) -> f64 {
    let n = points.rows;
    assert_eq!(labels.len(), n);
    let classes: std::collections::BTreeSet<usize> = labels.iter().copied().collect();
    if classes.len() < 2 || n < 3 {
        return 0.0;
    }
    let mut total = 0.0;
    let mut counted = 0usize;
    for i in 0..n {
        // mean distance to own cluster (a) and nearest other cluster (b)
        let mut own_sum = 0.0;
        let mut own_cnt = 0usize;
        let mut other: std::collections::BTreeMap<usize, (f64, usize)> = Default::default();
        for j in 0..n {
            if i == j {
                continue;
            }
            let d = dist2(points.row(i), points.row(j)).sqrt();
            if labels[j] == labels[i] {
                own_sum += d;
                own_cnt += 1;
            } else {
                let e = other.entry(labels[j]).or_insert((0.0, 0));
                e.0 += d;
                e.1 += 1;
            }
        }
        if own_cnt == 0 || other.is_empty() {
            continue;
        }
        let a = own_sum / own_cnt as f64;
        let b = other
            .values()
            .map(|(s, c)| s / *c as f64)
            .fold(f64::INFINITY, f64::min);
        total += (b - a) / a.max(b);
        counted += 1;
    }
    if counted == 0 {
        0.0
    } else {
        total / counted as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three tight, well-separated Gaussian blobs in 10-d.
    fn blobs(n_per: usize, seed: u64) -> (Mat, Vec<usize>) {
        let mut rng = Pcg64::seed(seed);
        let centers = [5.0, -5.0, 0.0];
        let mut rows = Vec::new();
        let mut labels = Vec::new();
        for (c, &center) in centers.iter().enumerate() {
            for _ in 0..n_per {
                let mut row = vec![0.0; 10];
                for (k, item) in row.iter_mut().enumerate() {
                    let mu = if k % 3 == c { center } else { 0.0 };
                    *item = mu + rng.normal() * 0.3;
                }
                rows.push(row);
                labels.push(c);
            }
        }
        (Mat::from_rows(&rows), labels)
    }

    #[test]
    fn separates_blobs() {
        let (x, labels) = blobs(30, 0);
        let emb = tsne(&x, &TsneConfig { iterations: 300, perplexity: 15.0, ..Default::default() }).unwrap();
        let s = silhouette(&emb, &labels);
        assert!(s > 0.5, "silhouette {s}");
    }

    #[test]
    fn output_shape_and_finite() {
        let (x, _) = blobs(10, 1);
        let emb = tsne(&x, &TsneConfig { iterations: 50, perplexity: 5.0, ..Default::default() }).unwrap();
        assert_eq!((emb.rows, emb.cols), (30, 2));
        assert!(emb.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, _) = blobs(8, 2);
        let cfg = TsneConfig { iterations: 50, perplexity: 5.0, ..Default::default() };
        let a = tsne(&x, &cfg).unwrap();
        let b = tsne(&x, &cfg).unwrap();
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn rejects_bad_inputs() {
        let (x, _) = blobs(2, 3); // n = 6
        assert!(tsne(&x, &TsneConfig { perplexity: 10.0, ..Default::default() }).is_err());
        let tiny = Mat::zeros(3, 4);
        assert!(tsne(&tiny, &TsneConfig::default()).is_err());
    }

    #[test]
    fn silhouette_of_perfect_split_near_one() {
        // two distant point pairs
        let pts = Mat::from_rows(&[
            vec![0.0, 0.0],
            vec![0.1, 0.0],
            vec![10.0, 0.0],
            vec![10.1, 0.0],
        ]);
        let s = silhouette(&pts, &[0, 0, 1, 1]);
        assert!(s > 0.9, "{s}");
    }

    #[test]
    fn silhouette_of_mixed_labels_low() {
        let pts = Mat::from_rows(&[
            vec![0.0, 0.0],
            vec![0.1, 0.0],
            vec![0.2, 0.0],
            vec![0.3, 0.0],
        ]);
        let s = silhouette(&pts, &[0, 1, 0, 1]);
        assert!(s < 0.2, "{s}");
    }

    #[test]
    fn silhouette_single_class_zero() {
        let pts = Mat::from_rows(&[vec![0.0], vec![1.0], vec![2.0]]);
        assert_eq!(silhouette(&pts, &[0, 0, 0]), 0.0);
    }
}
