//! Minimal JSON reader/writer (no serde in the offline build).
//!
//! Used for the artifact `manifest.json` produced by the python compile path
//! and for structured metric/experiment dumps.  Supports the full JSON value
//! grammar (objects, arrays, strings with escapes, numbers, booleans, null);
//! numbers are held as f64 which is lossless for every quantity we exchange.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number, held as f64.
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors ----

    /// Object member `key`; errors on non-objects or a missing key.
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| anyhow!("missing key `{key}`")),
            _ => bail!("not an object (looking up `{key}`)"),
        }
    }

    /// Numeric value; errors otherwise.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("not a number: {self:?}"),
        }
    }

    /// Non-negative integer value; errors otherwise.
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("not a non-negative integer: {x}");
        }
        Ok(x as usize)
    }

    /// String value; errors otherwise.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string: {self:?}"),
        }
    }

    /// Array value; errors otherwise.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array: {self:?}"),
        }
    }

    /// Object value; errors otherwise.
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object: {self:?}"),
        }
    }

    /// Array of numbers → Vec<f64>.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    /// Array of numbers → Vec<usize> (shapes).
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }

    // ---- parsing ----

    /// Parse a complete JSON document from text.
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    /// Parse a JSON file from disk.
    pub fn parse_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Json::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }

    // ---- writing ----

    /// Serialize to compact JSON text.
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Builder helpers for emitting JSON from experiment code.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Array of numbers.
pub fn arr_f64(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

/// A number.
pub fn num(x: f64) -> Json {
    Json::Num(x)
}

/// A string.
pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            bail!("expected `{}` at offset {}, found `{}`", c as char, self.i, self.peek()? as char)
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("unexpected `{}` at offset {}", c as char, self.i),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected `,` or `}}`, found `{}` at {}", c as char, self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected `,` or `]`, found `{}` at {}", c as char, self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", e as char),
                    }
                }
                c => {
                    // copy raw UTF-8 bytes through
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated UTF-8");
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek()? == b'-' {
            self.i += 1;
        }
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().with_context(|| format!("bad number `{text}`"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\" A");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = Json::parse("\"héllo ☃\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo ☃");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\":}").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"cfg":{"n":20,"p":1409},"vals":[1.5,-2,0.25],"name":"fd-dsgt","ok":true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn roundtrip_escapes() {
        let j = Json::Str("line1\nline2\t\"quoted\"\\".into());
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn shape_accessor() {
        let j = Json::parse("[20, 1409]").unwrap();
        assert_eq!(j.as_shape().unwrap(), vec![20, 1409]);
        assert!(Json::parse("[1.5]").unwrap().as_shape().is_err());
        assert!(Json::parse("[-1]").unwrap().as_shape().is_err());
    }

    #[test]
    fn builders() {
        let j = obj(vec![("a", num(1.0)), ("b", arr_f64(&[1.0, 2.0])), ("s", s("x"))]);
        assert_eq!(
            j.to_string(),
            r#"{"a":1,"b":[1,2],"s":"x"}"#
        );
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "version": 1,
          "config": {"n": 20, "d": 42, "p": 1409},
          "artifacts": {"grad_step": {"file": "grad_step.hlo.txt",
                         "inputs": [[1409],[20,42],[20]], "outputs": [[],[1409]]}},
          "goldens": {"grad_step": {"loss": 0.7, "grad_head": [0.1, -0.2]}}
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.get("config").unwrap().get("p").unwrap().as_usize().unwrap(), 1409);
        let ins = j.get("artifacts").unwrap().get("grad_step").unwrap().get("inputs").unwrap();
        assert_eq!(ins.as_arr().unwrap()[1].as_shape().unwrap(), vec![20, 42]);
    }
}
