//! Hand-rolled CLI argument parser (no clap in the offline build).
//!
//! Grammar: `decfl <subcommand> [--key value]... [--flag]...`
//! Flags are declared by each subcommand through [`Args::get_*`] accessors;
//! unknown flags are rejected by [`Args::finish`] so typos fail loudly.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// The leading subcommand, if any (`decfl train ...` → `train`).
    pub subcommand: Option<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.subcommand = Some(it.next().unwrap());
            }
        }
        while let Some(a) = it.next() {
            let Some(key) = a.strip_prefix("--") else {
                bail!("unexpected positional argument `{a}` (only one subcommand allowed)");
            };
            if key.is_empty() {
                bail!("bare `--` not supported");
            }
            // `--key=value` or `--key value` or boolean `--key`
            if let Some((k, v)) = key.split_once('=') {
                if out.options.insert(k.to_string(), v.to_string()).is_some() {
                    bail!("duplicate option --{k}");
                }
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                let v = it.next().unwrap();
                if out.options.insert(key.to_string(), v).is_some() {
                    bail!("duplicate option --{key}");
                }
            } else {
                out.flags.push(key.to_string());
            }
        }
        Ok(out)
    }

    /// Parse the process arguments (argv[0] excluded).
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// String option (`--key value`), `None` if absent.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.options.get(key).map(String::as_str)
    }

    /// Non-negative integer option; errors on a malformed value.
    pub fn get_usize(&self, key: &str) -> Result<Option<usize>> {
        self.mark(key);
        self.options
            .get(key)
            .map(|v| v.parse::<usize>().with_context(|| format!("--{key} expects an integer, got `{v}`")))
            .transpose()
    }

    /// u64 option (seeds); errors on a malformed value.
    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.mark(key);
        self.options
            .get(key)
            .map(|v| v.parse::<u64>().with_context(|| format!("--{key} expects an integer, got `{v}`")))
            .transpose()
    }

    /// Float option; errors on a malformed value.
    pub fn get_f64(&self, key: &str) -> Result<Option<f64>> {
        self.mark(key);
        self.options
            .get(key)
            .map(|v| v.parse::<f64>().with_context(|| format!("--{key} expects a number, got `{v}`")))
            .transpose()
    }

    /// Boolean flag presence (`--verbose`).
    pub fn has_flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    /// Comma-separated list option (`--qs 1,10,100`).
    pub fn get_usize_list(&self, key: &str) -> Result<Option<Vec<usize>>> {
        self.mark(key);
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse::<usize>().with_context(|| format!("--{key}: bad entry `{p}`")))
                .collect::<Result<Vec<_>>>()
                .map(Some),
        }
    }

    /// Comma-separated float list option (`--drops 0.2,0.4`).
    pub fn get_f64_list(&self, key: &str) -> Result<Option<Vec<f64>>> {
        self.mark(key);
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|p| p.trim().parse::<f64>().with_context(|| format!("--{key}: bad entry `{p}`")))
                .collect::<Result<Vec<_>>>()
                .map(Some),
        }
    }

    /// Was `--key` given on the command line at all (option or flag)?
    /// Unlike the accessors this answers *presence*, letting subcommands
    /// bail loudly on flags they would otherwise silently ignore.
    pub fn provided(&self, key: &str) -> bool {
        self.options.contains_key(key) || self.flags.iter().any(|f| f == key)
    }

    /// Error on any option/flag that no accessor ever looked at.
    pub fn finish(&self) -> Result<()> {
        let seen = self.consumed.borrow();
        let mut unknown: Vec<&str> = self
            .options
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !seen.iter().any(|s| s == *k))
            .map(String::as_str)
            .collect();
        unknown.dedup();
        if !unknown.is_empty() {
            bail!("unknown option(s): {}", unknown.iter().map(|k| format!("--{k}")).collect::<Vec<_>>().join(", "));
        }
        Ok(())
    }
}

/// Apply shared experiment-config overrides that most subcommands accept.
pub fn apply_common_overrides(args: &Args, cfg: &mut crate::config::ExperimentConfig) -> Result<()> {
    if let Some(path) = args.get_str("config") {
        *cfg = crate::config::ExperimentConfig::from_file(std::path::Path::new(path))?;
    }
    if let Some(v) = args.get_str("algo") {
        cfg.algo = crate::config::AlgoKind::parse(v)?;
    }
    if let Some(v) = args.get_str("mode") {
        cfg.mode = crate::config::Mode::parse(v)?;
    }
    if let Some(v) = args.get_str("driver") {
        cfg.driver = v.to_string();
    }
    if let Some(v) = args.get_f64("staleness-s")? {
        cfg.staleness_s = v;
    }
    if let Some(v) = args.get_f64("sim-budget-s")? {
        cfg.sim_budget_s = v;
    }
    if let Some(v) = args.get_str("net-validate") {
        cfg.net_validate = v.to_string();
    }
    if let Some(v) = args.get_str("backend") {
        cfg.backend = crate::config::Backend::parse(v)?;
    }
    if let Some(v) = args.get_usize("steps")? {
        cfg.total_steps = v;
    }
    if let Some(v) = args.get_usize("q")? {
        cfg.q = v;
    }
    if let Some(v) = args.get_f64("alpha0")? {
        cfg.alpha0 = v;
    }
    if let Some(v) = args.get_str("topology") {
        cfg.topology = v.to_string();
    }
    if let Some(v) = args.get_str("mixing") {
        cfg.mixing = v.to_string();
    }
    if let Some(v) = args.get_str("net-plan") {
        cfg.net_plan = v.to_string();
    }
    if let Some(v) = args.get_usize("rewire-every")? {
        cfg.rewire_every = v;
    }
    if let Some(v) = args.get_f64("edge-drop")? {
        cfg.edge_drop = v;
    }
    if let Some(v) = args.get_f64("churn")? {
        cfg.churn = v;
    }
    if let Some(v) = args.get_str("compute-plan") {
        cfg.compute_plan = v.to_string();
    }
    if let Some(v) = args.get_str("tiers") {
        cfg.compute_tiers = v.to_string();
    }
    if let Some(v) = args.get_f64("slow-frac")? {
        cfg.slow_frac = v;
    }
    if let Some(v) = args.get_f64("sigma")? {
        cfg.compute_sigma = v;
    }
    if let Some(v) = args.get_str("compress") {
        cfg.compress = v.to_string();
    }
    if let Some(v) = args.get_f64("topk-frac")? {
        cfg.topk_frac = v;
    }
    if args.has_flag("error-feedback") {
        cfg.error_feedback = true;
    }
    if let Some(v) = args.get_str("attack-plan") {
        cfg.attack_plan = v.to_string();
    }
    if let Some(v) = args.get_f64("attack-frac")? {
        cfg.attack_frac = v;
    }
    if let Some(v) = args.get_f64("attack-scale")? {
        cfg.attack_scale = v;
    }
    if let Some(v) = args.get_usize("attack-age")? {
        cfg.attack_age = v;
    }
    if let Some(v) = args.get_str("robust-rule") {
        cfg.robust_rule = v.to_string();
    }
    if let Some(v) = args.get_f64("robust-trim")? {
        cfg.robust_trim = v;
    }
    if let Some(v) = args.get_str("dp") {
        cfg.dp = v.to_string();
    }
    if let Some(v) = args.get_f64("dp-clip")? {
        cfg.dp_clip = v;
    }
    if let Some(v) = args.get_f64("dp-sigma")? {
        cfg.dp_sigma = v;
    }
    if let Some(v) = args.get_f64("dp-delta")? {
        cfg.dp_delta = v;
    }
    if let Some(v) = args.get_f64("drop-prob")? {
        cfg.drop_prob = v;
    }
    if let Some(v) = args.get_usize("shard-nodes")? {
        cfg.shard_nodes = v;
    }
    if let Some(v) = args.get_usize("hot-shards")? {
        cfg.hot_shards = v;
    }
    if let Some(v) = args.get_f64("heterogeneity")? {
        cfg.heterogeneity = v;
    }
    if let Some(v) = args.get_u64("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = args.get_usize("threads")? {
        cfg.threads = v;
    }
    if let Some(v) = args.get_str("artifacts") {
        cfg.artifacts_dir = v.to_string();
    }
    if let Some(v) = args.get_str("out") {
        cfg.out = Some(v.to_string());
    }
    if let Some(v) = args.get_usize("eval-every")? {
        cfg.eval_every = v;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "--algo", "fd-dsgt", "--steps", "1000", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.get_str("algo"), Some("fd-dsgt"));
        assert_eq!(a.get_usize("steps").unwrap(), Some(1000));
        assert!(a.has_flag("verbose"));
        assert!(a.finish().is_ok());
    }

    #[test]
    fn equals_form() {
        let a = parse(&["train", "--q=50", "--alpha0=0.05"]);
        assert_eq!(a.get_usize("q").unwrap(), Some(50));
        assert_eq!(a.get_f64("alpha0").unwrap(), Some(0.05));
    }

    #[test]
    fn lists() {
        let a = parse(&["sweep", "--qs", "1,10,100", "--hets", "0.0, 0.5, 1.0"]);
        assert_eq!(a.get_usize_list("qs").unwrap(), Some(vec![1, 10, 100]));
        assert_eq!(a.get_f64_list("hets").unwrap(), Some(vec![0.0, 0.5, 1.0]));
    }

    #[test]
    fn provided_reports_presence_without_consuming() {
        let a = parse(&["train", "--topology", "ring", "--verbose"]);
        assert!(a.provided("topology"));
        assert!(a.provided("verbose"));
        assert!(!a.provided("mixing"));
    }

    #[test]
    fn driver_overrides_apply() {
        let a = parse(&[
            "train", "--driver", "async", "--staleness-s", "0.25", "--net-validate", "approx",
            "--sim-budget-s", "1.5",
        ]);
        let mut cfg = crate::config::ExperimentConfig::default();
        super::apply_common_overrides(&a, &mut cfg).unwrap();
        assert_eq!(cfg.driver, "async");
        assert!((cfg.staleness_s - 0.25).abs() < 1e-12);
        assert_eq!(cfg.net_validate, "approx");
        assert!((cfg.sim_budget_s - 1.5).abs() < 1e-12);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn net_plan_overrides_apply() {
        let a = parse(&["train", "--net-plan", "churn", "--churn", "0.2", "--rewire-every", "3"]);
        let mut cfg = crate::config::ExperimentConfig::default();
        super::apply_common_overrides(&a, &mut cfg).unwrap();
        assert_eq!(cfg.net_plan, "churn");
        assert!((cfg.churn - 0.2).abs() < 1e-12);
        assert_eq!(cfg.rewire_every, 3);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn compute_plan_overrides_apply() {
        let a = parse(&[
            "train", "--compute-plan", "fixed-tiers", "--tiers", "1.0,0.25",
            "--slow-frac", "0.3", "--sigma", "0.9",
        ]);
        let mut cfg = crate::config::ExperimentConfig::default();
        super::apply_common_overrides(&a, &mut cfg).unwrap();
        assert_eq!(cfg.compute_plan, "fixed-tiers");
        assert_eq!(cfg.compute_tiers, "1.0,0.25");
        assert!((cfg.slow_frac - 0.3).abs() < 1e-12);
        assert!((cfg.compute_sigma - 0.9).abs() < 1e-12);
        assert!(a.finish().is_ok());
        // defaults untouched when the flags are absent
        let b = parse(&["train"]);
        let mut cfg = crate::config::ExperimentConfig::default();
        super::apply_common_overrides(&b, &mut cfg).unwrap();
        assert_eq!(cfg.compute_plan, "uniform");
    }

    #[test]
    fn compress_overrides_apply() {
        let a = parse(&["train", "--compress", "topk", "--topk-frac", "0.05", "--error-feedback"]);
        let mut cfg = crate::config::ExperimentConfig::default();
        super::apply_common_overrides(&a, &mut cfg).unwrap();
        assert_eq!(cfg.compress, "topk");
        assert!((cfg.topk_frac - 0.05).abs() < 1e-12);
        assert!(cfg.error_feedback);
        assert!(a.finish().is_ok());
        // defaults untouched when the flags are absent
        let b = parse(&["train"]);
        let mut cfg = crate::config::ExperimentConfig::default();
        super::apply_common_overrides(&b, &mut cfg).unwrap();
        assert_eq!(cfg.compress, "none");
        assert!(!cfg.error_feedback);
    }

    #[test]
    fn adversary_robust_dp_overrides_apply() {
        let a = parse(&[
            "train", "--attack-plan", "scaled-noise", "--attack-frac", "0.1",
            "--attack-scale", "5.0", "--attack-age", "3", "--robust-rule", "krum",
            "--robust-trim", "0.3", "--dp", "gaussian", "--dp-clip", "0.5",
            "--dp-sigma", "1.2", "--dp-delta", "1e-6",
        ]);
        let mut cfg = crate::config::ExperimentConfig::default();
        super::apply_common_overrides(&a, &mut cfg).unwrap();
        assert_eq!(cfg.attack_plan, "scaled-noise");
        assert!((cfg.attack_frac - 0.1).abs() < 1e-12);
        assert!((cfg.attack_scale - 5.0).abs() < 1e-12);
        assert_eq!(cfg.attack_age, 3);
        assert_eq!(cfg.robust_rule, "krum");
        assert!((cfg.robust_trim - 0.3).abs() < 1e-12);
        assert_eq!(cfg.dp, "gaussian");
        assert!((cfg.dp_clip - 0.5).abs() < 1e-12);
        assert!((cfg.dp_sigma - 1.2).abs() < 1e-12);
        assert!((cfg.dp_delta - 1e-6).abs() < 1e-18);
        assert!(a.finish().is_ok());
        // honest defaults untouched when the flags are absent
        let b = parse(&["train"]);
        let mut cfg = crate::config::ExperimentConfig::default();
        super::apply_common_overrides(&b, &mut cfg).unwrap();
        assert_eq!(cfg.attack_plan, "none");
        assert_eq!(cfg.robust_rule, "mean");
        assert_eq!(cfg.dp, "off");
    }

    #[test]
    fn state_sharding_overrides_apply() {
        let a = parse(&["train", "--shard-nodes", "512", "--hot-shards", "3"]);
        let mut cfg = crate::config::ExperimentConfig::default();
        super::apply_common_overrides(&a, &mut cfg).unwrap();
        assert_eq!(cfg.shard_nodes, 512);
        assert_eq!(cfg.hot_shards, 3);
        assert!(a.finish().is_ok());
        // defaults untouched when the flags are absent: unsharded resident slabs
        let b = parse(&["train"]);
        let mut cfg = crate::config::ExperimentConfig::default();
        super::apply_common_overrides(&b, &mut cfg).unwrap();
        assert_eq!(cfg.shard_nodes, 0);
        assert_eq!(cfg.hot_shards, 4);
    }

    #[test]
    fn unknown_option_rejected_by_finish() {
        let a = parse(&["train", "--bogus", "1"]);
        let _ = a.get_str("algo");
        assert!(a.finish().is_err());
    }

    #[test]
    fn duplicate_rejected() {
        assert!(Args::parse(["--a", "1", "--a", "2"].iter().map(|s| s.to_string())).is_err());
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["x", "--shift", "-1.5"]);
        assert_eq!(a.get_f64("shift").unwrap(), Some(-1.5));
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["x", "--steps", "many"]);
        assert!(a.get_usize("steps").is_err());
    }

    #[test]
    fn no_subcommand() {
        let a = parse(&["--help"]);
        assert_eq!(a.subcommand, None);
        assert!(a.has_flag("help"));
    }
}
