//! Deterministic per-node minibatch sampling and parameter initialization.
//!
//! Both execution drivers (fused and actors) draw batches through this type
//! with identical per-node RNG streams, so a run is reproducible *and* the
//! two drivers produce the same trajectory on the same backend — the
//! equivalence the integration tests assert.

use crate::data::Shard;
use crate::rng::Pcg64;

/// Per-node batch sampler: `m` indices without replacement per batch.
pub struct NodeSampler {
    rng: Pcg64,
    m: usize,
    /// Reusable index scratch (partial Fisher–Yates permutation) — batch
    /// draws are allocation-free once its capacity settles (§Perf).
    perm: Vec<usize>,
}

impl NodeSampler {
    /// Stream is keyed by (seed, node id) only — independent of driver.
    pub fn new(seed: u64, node: usize, m: usize) -> Self {
        NodeSampler { rng: Pcg64::new(seed, 0xBA7C4 + node as u64), m, perm: Vec::new() }
    }

    /// Sample one batch into `x_out [m*d]`, `y_out [m]`.
    pub fn batch(&mut self, shard: &Shard, x_out: &mut [f32], y_out: &mut [f32]) {
        let m = self.m;
        let (rng, perm) = (&mut self.rng, &mut self.perm);
        perm.clear();
        if shard.n >= m {
            // identical draw sequence and results as `Pcg64::sample_indices`
            // (partial Fisher–Yates), minus its per-call allocation
            perm.extend(0..shard.n);
            for i in 0..m {
                let j = rng.range(i, shard.n);
                perm.swap(i, j);
            }
        } else {
            // tiny shard: sample with replacement
            for _ in 0..m {
                let i = rng.range(0, shard.n);
                perm.push(i);
            }
        }
        shard.gather(&perm[..m], x_out, y_out);
    }

    /// Sample `count` consecutive batches into flat `[count*m*d]` buffers.
    pub fn batches(&mut self, shard: &Shard, count: usize, x_out: &mut [f32], y_out: &mut [f32]) {
        let d = shard.d;
        for c in 0..count {
            let (xs, ys) = (
                &mut x_out[c * self.m * d..(c + 1) * self.m * d],
                &mut y_out[c * self.m..(c + 1) * self.m],
            );
            self.batch(shard, xs, ys);
        }
    }
}

/// Per-node initial parameters: node-keyed stream so every hospital starts
/// at a different point (the consensus-error curve starts > 0, as in any
/// real decentralized deployment with local initialization).
pub fn init_theta(seed: u64, node: usize, model: &crate::algo::native::NativeModel) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, 0x1417 + node as u64);
    model.init(&mut rng)
}

/// Stacked `[n, p]` initial parameters.
pub fn init_thetas(seed: u64, n: usize, model: &crate::algo::native::NativeModel) -> Vec<f32> {
    let mut out = Vec::with_capacity(n * model.p());
    for i in 0..n {
        out.extend_from_slice(&init_theta(seed, i, model));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::native::NativeModel;
    use crate::data::{generate, DataConfig};

    fn shard() -> Shard {
        let ds = generate(&DataConfig {
            n_hospitals: 2,
            records_per_hospital: 50,
            records_jitter: 0,
            ..DataConfig::default()
        })
        .unwrap();
        ds.shards[0].clone()
    }

    #[test]
    fn same_stream_same_batches() {
        let s = shard();
        let mut a = NodeSampler::new(9, 3, 8);
        let mut b = NodeSampler::new(9, 3, 8);
        let mut xa = vec![0.0; 8 * s.d];
        let mut ya = vec![0.0; 8];
        let mut xb = vec![0.0; 8 * s.d];
        let mut yb = vec![0.0; 8];
        for _ in 0..5 {
            a.batch(&s, &mut xa, &mut ya);
            b.batch(&s, &mut xb, &mut yb);
            assert_eq!(xa, xb);
            assert_eq!(ya, yb);
        }
    }

    #[test]
    fn different_nodes_different_batches() {
        let s = shard();
        let mut a = NodeSampler::new(9, 0, 8);
        let mut b = NodeSampler::new(9, 1, 8);
        let mut xa = vec![0.0; 8 * s.d];
        let mut ya = vec![0.0; 8];
        let mut xb = vec![0.0; 8 * s.d];
        let mut yb = vec![0.0; 8];
        a.batch(&s, &mut xa, &mut ya);
        b.batch(&s, &mut xb, &mut yb);
        assert_ne!(xa, xb);
    }

    #[test]
    fn batches_equals_repeated_batch() {
        let s = shard();
        let mut a = NodeSampler::new(3, 0, 4);
        let mut b = NodeSampler::new(3, 0, 4);
        let mut xa = vec![0.0; 3 * 4 * s.d];
        let mut ya = vec![0.0; 3 * 4];
        a.batches(&s, 3, &mut xa, &mut ya);
        for c in 0..3 {
            let mut xb = vec![0.0; 4 * s.d];
            let mut yb = vec![0.0; 4];
            b.batch(&s, &mut xb, &mut yb);
            assert_eq!(&xa[c * 4 * s.d..(c + 1) * 4 * s.d], &xb[..]);
            assert_eq!(&ya[c * 4..(c + 1) * 4], &yb[..]);
        }
    }

    #[test]
    fn tiny_shard_with_replacement() {
        let big = shard();
        let tiny = Shard { n: 3, d: big.d, x: big.x[..3 * big.d].to_vec(), y: big.y[..3].to_vec() };
        let mut s = NodeSampler::new(0, 0, 8);
        let mut x = vec![0.0; 8 * tiny.d];
        let mut y = vec![0.0; 8];
        s.batch(&tiny, &mut x, &mut y); // must not panic
    }

    #[test]
    fn init_thetas_distinct_per_node() {
        let m = NativeModel::new(6, 4);
        let stacked = init_thetas(7, 3, &m);
        assert_eq!(stacked.len(), 3 * m.p());
        assert_ne!(&stacked[..m.p()], &stacked[m.p()..2 * m.p()]);
        // deterministic
        assert_eq!(stacked, init_thetas(7, 3, &m));
        assert_eq!(&stacked[m.p()..2 * m.p()], &init_theta(7, 1, &m)[..]);
    }
}
