//! L3 coordinator: wires config → data → graph → mixing → driver.
//!
//! [`run`] is the single entry point the CLI, examples, and benches use: it
//! builds the federated cohort, the hospital graph and its mixing matrix
//! (validated against Assumption 1), selects the compute backend (PJRT
//! artifacts or the native twin) and the execution driver (fused or actors),
//! dispatches baselines, and returns the metric log.
//!
//! Every trainer dispatched here is a thin adapter over the unified round
//! loop in [`crate::engine`] — the drivers differ only in where the phases
//! execute, never in the round structure.

pub mod actors;
pub mod baselines;
pub mod compute;
pub mod fused;
pub mod sampler;

use crate::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use crate::data::{generate, DataConfig, FederatedDataset};
use crate::graph::{Graph, Topology};
use crate::metrics::RunLog;
use crate::mixing::{self, Scheme};
use crate::rng::Pcg64;
use anyhow::{bail, Context, Result};

pub use compute::{Compute, NativeCompute, PjrtCompute};

/// Everything `run` assembled, exposed for examples/benches that need the
/// pieces (dataset for AUC, graph for reporting, ...).
pub struct Assembled {
    /// The synthetic federated cohort.
    pub ds: FederatedDataset,
    /// The hospital gossip graph.
    pub graph: Graph,
    /// Its validated mixing matrix (Assumption 1), stored sparse (CSR) so
    /// assembly never materializes an n×n array.
    pub w: crate::mixing::SparseW,
    /// `1 − |λ₂|` of `w` — the consensus-rate knob.  NaN when the config
    /// set `net.validate = skip` (the spectrum was never estimated).
    pub spectral_gap: f64,
}

/// Build dataset + graph + mixing matrix from a config.
pub fn assemble(cfg: &ExperimentConfig) -> Result<Assembled> {
    cfg.validate()?;
    let ds = generate(&DataConfig {
        n_hospitals: cfg.n,
        records_per_hospital: cfg.records_per_hospital,
        records_jitter: cfg.records_per_hospital / 10,
        ad_prevalence: cfg.ad_prevalence,
        heterogeneity: cfg.heterogeneity,
        test_fraction: 0.1,
        seed: cfg.seed,
    })?;
    let topo = Topology::parse(&cfg.topology)?;
    let mut grng = Pcg64::new(cfg.seed, 0x6EA9);
    let graph = Graph::build(&topo, cfg.n, &mut grng)?;
    if !graph.is_connected() {
        bail!("generated graph is disconnected — Assumption 1 violated");
    }
    let w = mixing::build_sparse(&graph, Scheme::parse(&cfg.mixing)?);
    let level = mixing::ValidateLevel::parse(&cfg.net_validate)?;
    let v = mixing::validate_sparse_with(&w, level);
    if !v.holds() {
        bail!("mixing matrix violates Assumption 1: {v:?}");
    }
    Ok(Assembled { ds, graph, w, spectral_gap: v.spectral_gap })
}

/// Build the configured compute backend.  The native backend fans its
/// whole-network ops over `cfg.threads` workers (0 = auto) with
/// bitwise-deterministic results and carries the configured robust combine
/// rule (`robust.rule`); the PJRT artifacts lower the plain-mean kernels
/// only, so any adversarial axis on that backend is a loud error.
pub fn make_compute(cfg: &ExperimentConfig) -> Result<Box<dyn Compute>> {
    let rule = crate::algo::RobustRule::parse(&cfg.robust_rule, cfg.robust_trim)?;
    match cfg.backend {
        Backend::Native => Ok(Box::new(
            NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m)
                .with_threads(cfg.threads)
                .with_robust_rule(rule),
        )),
        Backend::Pjrt => {
            if crate::engine::adversary::perturb_active(cfg) || !rule.is_mean() {
                bail!(
                    "adversarial settings (attack.plan={}, robust.rule={}, dp={}) requested, \
                     but the PJRT artifacts lower the plain-mean gossip kernels only and \
                     would silently ignore them; rerun with --backend native",
                    cfg.attack_plan,
                    cfg.robust_rule,
                    cfg.dp
                );
            }
            let c = PjrtCompute::load(std::path::Path::new(&cfg.artifacts_dir))
                .context("loading PJRT artifacts")?;
            c.engine().check_config(cfg.n, cfg.d, cfg.hidden, cfg.m, cfg.q)?;
            Ok(Box::new(c))
        }
    }
}

/// Run a full experiment per the config; returns the metric log.
pub fn run(cfg: &ExperimentConfig) -> Result<RunLog> {
    let asm = assemble(cfg)?;
    run_on(cfg, &asm)
}

/// Run on pre-assembled pieces (benches reuse one dataset across algos).
pub fn run_on(cfg: &ExperimentConfig, asm: &Assembled) -> Result<RunLog> {
    let eval_compute = make_compute(cfg)?;
    match cfg.algo {
        AlgoKind::Centralized | AlgoKind::FedAvg if cfg.driver == "async" => {
            bail!(
                "`{}` runs the synchronous baseline protocol and has no async \
                 gossip driver; drop --driver async or pick a gossip algorithm \
                 (dsgd|dsgt|fd-dsgd|fd-dsgt)",
                cfg.algo.name()
            )
        }
        AlgoKind::Centralized | AlgoKind::FedAvg if cfg.shard_nodes > 0 => {
            bail!(
                "state.shard_nodes applies to gossip algorithms, but `{}` runs the \
                 synchronous baseline protocol with co-resident server state; drop \
                 --shard-nodes or pick a gossip algorithm (dsgd|dsgt|fd-dsgd|fd-dsgt)",
                cfg.algo.name()
            )
        }
        AlgoKind::Centralized => baselines::centralized(cfg, eval_compute.as_ref(), &asm.ds),
        AlgoKind::FedAvg => baselines::fedavg(cfg, eval_compute.as_ref(), &asm.ds),
        // sharded node state: the spill-backed shard sweep owns the whole
        // run (it bails loudly on async/actors and every unsupported axis)
        _ if cfg.shard_nodes > 0 => {
            crate::engine::shard::train_log(cfg, &asm.ds, &asm.graph, &asm.w)
        }
        _ if cfg.driver == "async" => {
            crate::engine::asynchrony::train(cfg, eval_compute.as_ref(), &asm.ds, &asm.graph, &asm.w)
        }
        _ => match cfg.mode {
            Mode::Fused => fused::train(cfg, eval_compute.as_ref(), &asm.ds, &asm.graph, &asm.w),
            Mode::Actors => {
                let factory = |_node: usize| make_compute(cfg);
                actors::train(cfg, &factory, eval_compute.as_ref(), &asm.ds, &asm.graph, &asm.w)
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn native_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.backend = Backend::Native;
        cfg.n = 5;
        cfg.hidden = 8;
        cfg.m = 8;
        cfg.q = 4;
        cfg.total_steps = 40;
        cfg.eval_every = 5;
        cfg.records_per_hospital = 60;
        cfg
    }

    #[test]
    fn assemble_validates_assumption_1() {
        let asm = assemble(&native_cfg()).unwrap();
        assert_eq!(asm.ds.n_hospitals(), 5);
        assert!(asm.spectral_gap > 0.0);
        assert!(asm.graph.is_connected());
    }

    #[test]
    fn run_every_algorithm_native() {
        for algo in [
            AlgoKind::Dsgd,
            AlgoKind::Dsgt,
            AlgoKind::FdDsgd,
            AlgoKind::FdDsgt,
            AlgoKind::FedAvg,
            AlgoKind::Centralized,
        ] {
            let mut cfg = native_cfg();
            cfg.algo = algo;
            let log = run(&cfg).unwrap();
            assert!(!log.rows.is_empty(), "{algo:?}");
            let first = log.rows.first().unwrap().loss;
            let last = log.rows.last().unwrap().loss;
            assert!(last < first, "{algo:?}: loss {first} -> {last}");
            assert!(last.is_finite());
        }
    }

    #[test]
    fn pjrt_backend_rejects_adversarial_axes_loudly() {
        // the bail fires before any artifact loading, so no artifacts needed
        for (attack, rule, dp) in [
            ("sign-flip", "mean", "off"),
            ("none", "median", "off"),
            ("none", "mean", "gaussian"),
        ] {
            let mut cfg = native_cfg();
            cfg.backend = Backend::Pjrt;
            cfg.attack_plan = attack.into();
            cfg.attack_frac = if attack == "none" { 0.0 } else { 0.2 };
            cfg.robust_rule = rule.into();
            cfg.dp = dp.into();
            let err = make_compute(&cfg).unwrap_err().to_string();
            assert!(err.contains("backend native"), "{attack}/{rule}/{dp}: {err}");
        }
    }

    #[test]
    fn run_actor_mode_native() {
        let mut cfg = native_cfg();
        cfg.mode = Mode::Actors;
        cfg.algo = AlgoKind::FdDsgt;
        let log = run(&cfg).unwrap();
        assert!(log.rows.last().unwrap().bytes > 0);
    }

    #[test]
    fn fd_beats_classic_per_comm_round_native() {
        // the paper's headline: FD variants reach low loss in far fewer
        // communication rounds
        let mut fd = native_cfg();
        fd.algo = AlgoKind::FdDsgt;
        fd.q = 10;
        fd.total_steps = 400;
        fd.eval_every = 1;
        let asm = assemble(&fd).unwrap();
        let log_fd = run_on(&fd, &asm).unwrap();

        let mut classic = fd.clone();
        classic.algo = AlgoKind::Dsgt;
        let log_classic = run_on(&classic, &asm).unwrap();

        // at equal comm rounds (40 for FD = all its rounds), FD is further along
        let fd_final = log_fd.rows.last().unwrap();
        let classic_at_same_rounds = log_classic
            .rows
            .iter()
            .filter(|r| r.comm_rounds <= fd_final.comm_rounds)
            .next_back()
            .unwrap();
        assert!(
            fd_final.loss < classic_at_same_rounds.loss,
            "fd {} vs classic {}",
            fd_final.loss,
            classic_at_same_rounds.loss
        );
    }
}
