//! Fused execution driver — a thin adapter over the unified round engine.
//!
//! The highest-throughput way to run the decentralized algorithms on a
//! single machine: every communication round is ONE whole-network
//! `local_steps_all` call plus ONE `dsgd_round`/`dsgt_round` call, with
//! communication charged analytically (`netsim::analytic` — byte-exact vs
//! the channel netsim).  The round loop itself lives in
//! [`crate::engine::RoundEngine`]; this module only picks the sync driver
//! with the gossip strategy matching `cfg.algo`.  The actor driver
//! (`actors.rs`) is the fidelity path.

use crate::config::ExperimentConfig;
use crate::data::FederatedDataset;
use crate::engine;
use crate::graph::Graph;
use crate::metrics::RunLog;
use crate::mixing::SparseW;
use anyhow::Result;

use super::compute::Compute;

/// Train with the fused driver. `w` must satisfy Assumption 1 over `graph`.
/// Rejects `cfg.drop_prob > 0` — loss injection needs the channel netsim
/// (`--mode actors`); the analytic accountant is lossless by construction.
pub fn train(
    cfg: &ExperimentConfig,
    compute: &dyn Compute,
    ds: &FederatedDataset,
    graph: &Graph,
    w: &SparseW,
) -> Result<RunLog> {
    let (log, _theta) = engine::train_decentralized(cfg, compute, ds, graph, w)?;
    Ok(log)
}

/// Train and also return the final stacked parameters of the SAME run —
/// the engine hands back θ directly, so there is no deterministic re-run.
/// Convenience for examples that need θ for test-set prediction.
pub fn train_returning_params(
    cfg: &ExperimentConfig,
    compute: &dyn Compute,
    ds: &FederatedDataset,
    graph: &Graph,
    w: &SparseW,
) -> Result<(RunLog, Vec<f32>)> {
    engine::train_decentralized(cfg, compute, ds, graph, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgoKind, Backend, Mode};
    use crate::coordinator::compute::NativeCompute;
    use crate::data::{generate, DataConfig};
    use crate::graph::Topology;
    use crate::mixing::{build_sparse, Scheme};
    use crate::rng::Pcg64;

    fn tiny_setup(
        algo: AlgoKind,
        q: usize,
        steps: usize,
    ) -> (ExperimentConfig, NativeCompute, FederatedDataset, Graph, SparseW) {
        let mut cfg = ExperimentConfig::default();
        cfg.n = 5;
        cfg.d = 42;
        cfg.hidden = 8;
        cfg.m = 10;
        cfg.q = q;
        cfg.algo = algo;
        cfg.total_steps = steps;
        cfg.eval_every = 1;
        cfg.mode = Mode::Fused;
        cfg.backend = Backend::Native;
        cfg.records_per_hospital = 80;
        let ds = generate(&DataConfig {
            n_hospitals: cfg.n,
            records_per_hospital: cfg.records_per_hospital,
            records_jitter: 0,
            heterogeneity: 0.5,
            ..DataConfig::default()
        })
        .unwrap();
        let graph = Graph::build(&Topology::Ring, cfg.n, &mut Pcg64::seed(1)).unwrap();
        let w = build_sparse(&graph, Scheme::Metropolis);
        let compute = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m);
        (cfg, compute, ds, graph, w)
    }

    #[test]
    fn dsgd_loss_decreases() {
        let (cfg, compute, ds, graph, w) = tiny_setup(AlgoKind::Dsgd, 1, 60);
        let log = train(&cfg, &compute, &ds, &graph, &w).unwrap();
        let first = log.rows.first().unwrap().loss;
        let last = log.rows.last().unwrap().loss;
        assert!(last < first - 0.02, "loss {first} -> {last}");
        assert_eq!(log.rows.last().unwrap().comm_rounds, 60);
    }

    #[test]
    fn fd_dsgt_converges_with_fewer_rounds() {
        let (cfg, compute, ds, graph, w) = tiny_setup(AlgoKind::FdDsgt, 10, 300);
        let log = train(&cfg, &compute, &ds, &graph, &w).unwrap();
        assert_eq!(log.rows.last().unwrap().comm_rounds, 30);
        assert_eq!(log.rows.last().unwrap().local_steps, 300);
        let first = log.rows.first().unwrap().loss;
        let last = log.rows.last().unwrap().loss;
        assert!(last < first - 0.02, "loss {first} -> {last}");
    }

    #[test]
    fn consensus_shrinks_under_gossip() {
        let (cfg, compute, ds, graph, w) = tiny_setup(AlgoKind::Dsgt, 1, 80);
        let log = train(&cfg, &compute, &ds, &graph, &w).unwrap();
        let c0 = log.rows.first().unwrap().consensus;
        let cl = log.rows.last().unwrap().consensus;
        assert!(cl < c0 * 0.5, "consensus {c0} -> {cl}");
    }

    #[test]
    fn dsgt_charges_double_bytes() {
        let (cfg_t, compute, ds, graph, w) = tiny_setup(AlgoKind::Dsgt, 1, 20);
        let log_t = train(&cfg_t, &compute, &ds, &graph, &w).unwrap();
        let mut cfg_d = cfg_t.clone();
        cfg_d.algo = AlgoKind::Dsgd;
        let log_d = train(&cfg_d, &compute, &ds, &graph, &w).unwrap();
        let bt = log_t.rows.last().unwrap().bytes;
        let bd = log_d.rows.last().unwrap().bytes;
        assert_eq!(bt, 2 * bd);
    }

    #[test]
    fn deterministic_across_runs() {
        let (cfg, compute, ds, graph, w) = tiny_setup(AlgoKind::FdDsgd, 5, 50);
        let a = train(&cfg, &compute, &ds, &graph, &w).unwrap();
        let b = train(&cfg, &compute, &ds, &graph, &w).unwrap();
        for (ra, rb) in a.rows.iter().zip(&b.rows) {
            assert_eq!(ra.loss, rb.loss);
            assert_eq!(ra.stationarity, rb.stationarity);
        }
    }

    #[test]
    fn eval_every_respected() {
        let (mut cfg, compute, ds, graph, w) = tiny_setup(AlgoKind::Dsgd, 1, 40);
        cfg.eval_every = 10;
        let log = train(&cfg, &compute, &ds, &graph, &w).unwrap();
        let rounds: Vec<u64> = log.rows.iter().map(|r| r.comm_rounds).collect();
        assert_eq!(rounds, vec![0, 10, 20, 30, 40]);
    }

    #[test]
    fn replay_matches_logged_trajectory() {
        let (cfg, compute, ds, graph, w) = tiny_setup(AlgoKind::FdDsgt, 5, 50);
        let (log, theta) = train_returning_params(&cfg, &compute, &ds, &graph, &w).unwrap();
        // evaluating the returned θ reproduces the last logged loss exactly
        let eval = compute.eval_full(&theta, &ds.shards).unwrap();
        assert_eq!(eval.0, log.rows.last().unwrap().loss);
    }

    #[test]
    fn drop_prob_is_rejected_not_ignored() {
        let (mut cfg, compute, ds, graph, w) = tiny_setup(AlgoKind::FdDsgt, 5, 20);
        cfg.drop_prob = 0.2;
        let err = train(&cfg, &compute, &ds, &graph, &w).unwrap_err();
        assert!(err.to_string().contains("--mode actors"), "{err}");
    }
}
