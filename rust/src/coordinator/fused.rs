//! Fused execution driver: one compute call per whole-network phase.
//!
//! The highest-throughput way to run the decentralized algorithms on a
//! single machine: every communication round is (at most) N `local_steps`
//! calls plus ONE `dsgd_round`/`dsgt_round` call covering all nodes, with
//! communication charged analytically (`netsim::analytic` — byte-exact
//! vs the channel netsim).  Used by the figure benches and sweeps; the
//! actor driver (`actors.rs`) is the fidelity path.

use crate::algo::native::NativeModel;
use crate::algo::{LrSchedule, RoundPlan};
use crate::config::ExperimentConfig;
use crate::data::FederatedDataset;
use crate::graph::Graph;
use crate::linalg::Mat;
use crate::metrics::{round_metrics, RunLog};
use crate::netsim::{analytic::Accountant, LinkModel};
use anyhow::{bail, Result};

use super::compute::Compute;
use super::sampler::{init_thetas, NodeSampler};

/// Train with the fused driver. `w` must satisfy Assumption 1 over `graph`.
pub fn train(
    cfg: &ExperimentConfig,
    compute: &dyn Compute,
    ds: &FederatedDataset,
    graph: &Graph,
    w: &Mat,
) -> Result<RunLog> {
    let n = ds.n_hospitals();
    let (d, _h, p) = compute.dims();
    if d != ds.d {
        bail!("backend d={d} vs dataset d={}", ds.d);
    }
    let q = cfg.algo.effective_q(cfg.q);
    let plan = RoundPlan::new(q);
    let sched = LrSchedule::new(cfg.alpha0);
    let rounds = plan.rounds_for(cfg.total_steps);
    let use_tracker = cfg.algo.uses_tracker();
    let m = cfg.m;

    if let Some(want) = compute.local_steps_len() {
        if plan.local_per_round > 0 && plan.local_per_round != want {
            bail!(
                "artifacts were lowered for Q={} (local phase {want}), config wants Q={q}; \
                 re-run `make artifacts Q={q}` or use --backend native",
                want + 1
            );
        }
    }

    let wf: Vec<f32> = crate::mixing::to_f32(w);
    let model = NativeModel::new(d, compute.dims().1);
    let mut theta = init_thetas(cfg.seed, n, &model);
    let mut samplers: Vec<NodeSampler> =
        (0..n).map(|i| NodeSampler::new(cfg.seed, i, m)).collect();

    let link = LinkModel {
        latency_s: cfg.latency_s,
        bandwidth_bps: cfg.bandwidth_bps,
        drop_prob: 0.0, // loss injection is actor-mode-only
    };
    let mut acct = Accountant::new(graph, link);
    let mut log = RunLog::new(cfg.algo.name());
    let started = std::time::Instant::now();

    // scratch buffers reused across rounds (no alloc in the hot loop);
    // the local phase is whole-network shaped for the fused artifact (§Perf)
    let local = plan.local_per_round;
    let mut lx = vec![0.0f32; n * local * m * d];
    let mut ly = vec![0.0f32; n * local * m];
    let mut cx = vec![0.0f32; n * m * d];
    let mut cy = vec![0.0f32; n * m];

    // DSGT state: tracker Y and previous gradient G (init with a fresh batch)
    let (mut y_tr, mut g_prev) = if use_tracker {
        let mut g0 = vec![0.0f32; n * p];
        for i in 0..n {
            let (bx, by) = (&mut cx[i * m * d..(i + 1) * m * d], &mut cy[i * m..(i + 1) * m]);
            samplers[i].batch(&ds.shards[i], bx, by);
            let (_, gi) = compute.grad_step(&theta[i * p..(i + 1) * p], bx, by)?;
            g0[i * p..(i + 1) * p].copy_from_slice(&gi);
        }
        (g0.clone(), g0)
    } else {
        (Vec::new(), Vec::new())
    };

    // round 0 metrics (initial point)
    let eval0 = compute.eval_full(&theta, &ds.shards)?;
    log.push(round_metrics(0, 0, eval0, acct.snapshot(), started.elapsed().as_secs_f64()));

    for round in 1..=rounds {
        // ---- local phase: Q-1 eq.-4 steps per node, one fused call ----
        if local > 0 {
            let lrs = sched.local_lrs(round, q, local);
            for i in 0..n {
                samplers[i].batches(
                    &ds.shards[i],
                    local,
                    &mut lx[i * local * m * d..(i + 1) * local * m * d],
                    &mut ly[i * local * m..(i + 1) * local * m],
                );
            }
            let (t_next, _losses) = compute.local_steps_all(&theta, &lx, &ly, &lrs)?;
            theta = t_next;
            acct.local_compute(local as u64, cfg.compute_s_per_step);
        }

        // ---- communication step (eq. 2 / eq. 3) ----
        for i in 0..n {
            let (bx, by) = (&mut cx[i * m * d..(i + 1) * m * d], &mut cy[i * m..(i + 1) * m]);
            samplers[i].batch(&ds.shards[i], bx, by);
        }
        let lr = sched.comm_lr(round, q);
        if use_tracker {
            let (t2, y2, g2, _losses) =
                compute.dsgt_round(&wf, &theta, &y_tr, &g_prev, &cx, &cy, lr)?;
            theta = t2;
            y_tr = y2;
            g_prev = g2;
            acct.local_compute(1, cfg.compute_s_per_step);
            acct.comm_round(p, 2); // θ and ϑ
        } else {
            let (t2, _losses) = compute.dsgd_round(&wf, &theta, &cx, &cy, lr)?;
            theta = t2;
            acct.local_compute(1, cfg.compute_s_per_step);
            acct.comm_round(p, 1);
        }

        // ---- metrics ----
        if round % cfg.eval_every.max(1) == 0 || round == rounds {
            let eval = compute.eval_full(&theta, &ds.shards)?;
            log.push(round_metrics(
                round as u64,
                (round * q) as u64,
                eval,
                acct.snapshot(),
                started.elapsed().as_secs_f64(),
            ));
        }
    }

    Ok(log)
}

/// Final stacked parameters of a fused run (re-runs deterministically).
/// Convenience for examples that need θ for test-set prediction.
pub fn train_returning_params(
    cfg: &ExperimentConfig,
    compute: &dyn Compute,
    ds: &FederatedDataset,
    graph: &Graph,
    w: &Mat,
) -> Result<(RunLog, Vec<f32>)> {
    // same loop, but keep θ — implemented by a thin re-run wrapper to keep
    // `train` allocation-free; cost is identical and determinism guarantees
    // the same trajectory.
    let log = train(cfg, compute, ds, graph, w)?;
    let theta = replay_final_params(cfg, compute, ds, w)?;
    Ok((log, theta))
}

fn replay_final_params(
    cfg: &ExperimentConfig,
    compute: &dyn Compute,
    ds: &FederatedDataset,
    w: &Mat,
) -> Result<Vec<f32>> {
    let n = ds.n_hospitals();
    let (d, h, p) = compute.dims();
    let q = cfg.algo.effective_q(cfg.q);
    let plan = RoundPlan::new(q);
    let sched = LrSchedule::new(cfg.alpha0);
    let rounds = plan.rounds_for(cfg.total_steps);
    let use_tracker = cfg.algo.uses_tracker();
    let m = cfg.m;
    let wf: Vec<f32> = crate::mixing::to_f32(w);
    let model = NativeModel::new(d, h);
    let mut theta = init_thetas(cfg.seed, n, &model);
    let mut samplers: Vec<NodeSampler> =
        (0..n).map(|i| NodeSampler::new(cfg.seed, i, m)).collect();
    let local = plan.local_per_round;
    let mut lx = vec![0.0f32; n * local * m * d];
    let mut ly = vec![0.0f32; n * local * m];
    let mut cx = vec![0.0f32; n * m * d];
    let mut cy = vec![0.0f32; n * m];
    let (mut y_tr, mut g_prev) = if use_tracker {
        let mut g0 = vec![0.0f32; n * p];
        for i in 0..n {
            let (bx, by) = (&mut cx[i * m * d..(i + 1) * m * d], &mut cy[i * m..(i + 1) * m]);
            samplers[i].batch(&ds.shards[i], bx, by);
            let (_, gi) = compute.grad_step(&theta[i * p..(i + 1) * p], bx, by)?;
            g0[i * p..(i + 1) * p].copy_from_slice(&gi);
        }
        (g0.clone(), g0)
    } else {
        (Vec::new(), Vec::new())
    };
    for round in 1..=rounds {
        if local > 0 {
            let lrs = sched.local_lrs(round, q, local);
            for i in 0..n {
                samplers[i].batches(
                    &ds.shards[i],
                    local,
                    &mut lx[i * local * m * d..(i + 1) * local * m * d],
                    &mut ly[i * local * m..(i + 1) * local * m],
                );
            }
            let (t_next, _) = compute.local_steps_all(&theta, &lx, &ly, &lrs)?;
            theta = t_next;
        }
        for i in 0..n {
            let (bx, by) = (&mut cx[i * m * d..(i + 1) * m * d], &mut cy[i * m..(i + 1) * m]);
            samplers[i].batch(&ds.shards[i], bx, by);
        }
        let lr = sched.comm_lr(round, q);
        if use_tracker {
            let (t2, y2, g2, _) = compute.dsgt_round(&wf, &theta, &y_tr, &g_prev, &cx, &cy, lr)?;
            theta = t2;
            y_tr = y2;
            g_prev = g2;
        } else {
            let (t2, _) = compute.dsgd_round(&wf, &theta, &cx, &cy, lr)?;
            theta = t2;
        }
    }
    Ok(theta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgoKind, Backend, Mode};
    use crate::coordinator::compute::NativeCompute;
    use crate::data::{generate, DataConfig};
    use crate::graph::Topology;
    use crate::mixing::{build as build_w, Scheme};
    use crate::rng::Pcg64;

    fn tiny_setup(
        algo: AlgoKind,
        q: usize,
        steps: usize,
    ) -> (ExperimentConfig, NativeCompute, FederatedDataset, Graph, Mat) {
        let mut cfg = ExperimentConfig::default();
        cfg.n = 5;
        cfg.d = 42;
        cfg.hidden = 8;
        cfg.m = 10;
        cfg.q = q;
        cfg.algo = algo;
        cfg.total_steps = steps;
        cfg.eval_every = 1;
        cfg.mode = Mode::Fused;
        cfg.backend = Backend::Native;
        cfg.records_per_hospital = 80;
        let ds = generate(&DataConfig {
            n_hospitals: cfg.n,
            records_per_hospital: cfg.records_per_hospital,
            records_jitter: 0,
            heterogeneity: 0.5,
            ..DataConfig::default()
        })
        .unwrap();
        let graph = Graph::build(&Topology::Ring, cfg.n, &mut Pcg64::seed(1)).unwrap();
        let w = build_w(&graph, Scheme::Metropolis);
        let compute = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m);
        (cfg, compute, ds, graph, w)
    }

    #[test]
    fn dsgd_loss_decreases() {
        let (cfg, compute, ds, graph, w) = tiny_setup(AlgoKind::Dsgd, 1, 60);
        let log = train(&cfg, &compute, &ds, &graph, &w).unwrap();
        let first = log.rows.first().unwrap().loss;
        let last = log.rows.last().unwrap().loss;
        assert!(last < first - 0.02, "loss {first} -> {last}");
        assert_eq!(log.rows.last().unwrap().comm_rounds, 60);
    }

    #[test]
    fn fd_dsgt_converges_with_fewer_rounds() {
        let (cfg, compute, ds, graph, w) = tiny_setup(AlgoKind::FdDsgt, 10, 300);
        let log = train(&cfg, &compute, &ds, &graph, &w).unwrap();
        assert_eq!(log.rows.last().unwrap().comm_rounds, 30);
        assert_eq!(log.rows.last().unwrap().local_steps, 300);
        let first = log.rows.first().unwrap().loss;
        let last = log.rows.last().unwrap().loss;
        assert!(last < first - 0.02, "loss {first} -> {last}");
    }

    #[test]
    fn consensus_shrinks_under_gossip() {
        let (cfg, compute, ds, graph, w) = tiny_setup(AlgoKind::Dsgt, 1, 80);
        let log = train(&cfg, &compute, &ds, &graph, &w).unwrap();
        let c0 = log.rows.first().unwrap().consensus;
        let cl = log.rows.last().unwrap().consensus;
        assert!(cl < c0 * 0.5, "consensus {c0} -> {cl}");
    }

    #[test]
    fn dsgt_charges_double_bytes() {
        let (cfg_t, compute, ds, graph, w) = tiny_setup(AlgoKind::Dsgt, 1, 20);
        let log_t = train(&cfg_t, &compute, &ds, &graph, &w).unwrap();
        let mut cfg_d = cfg_t.clone();
        cfg_d.algo = AlgoKind::Dsgd;
        let log_d = train(&cfg_d, &compute, &ds, &graph, &w).unwrap();
        let bt = log_t.rows.last().unwrap().bytes;
        let bd = log_d.rows.last().unwrap().bytes;
        assert_eq!(bt, 2 * bd);
    }

    #[test]
    fn deterministic_across_runs() {
        let (cfg, compute, ds, graph, w) = tiny_setup(AlgoKind::FdDsgd, 5, 50);
        let a = train(&cfg, &compute, &ds, &graph, &w).unwrap();
        let b = train(&cfg, &compute, &ds, &graph, &w).unwrap();
        for (ra, rb) in a.rows.iter().zip(&b.rows) {
            assert_eq!(ra.loss, rb.loss);
            assert_eq!(ra.stationarity, rb.stationarity);
        }
    }

    #[test]
    fn eval_every_respected() {
        let (mut cfg, compute, ds, graph, w) = tiny_setup(AlgoKind::Dsgd, 1, 40);
        cfg.eval_every = 10;
        let log = train(&cfg, &compute, &ds, &graph, &w).unwrap();
        let rounds: Vec<u64> = log.rows.iter().map(|r| r.comm_rounds).collect();
        assert_eq!(rounds, vec![0, 10, 20, 30, 40]);
    }

    #[test]
    fn replay_matches_logged_trajectory() {
        let (cfg, compute, ds, graph, w) = tiny_setup(AlgoKind::FdDsgt, 5, 50);
        let (log, theta) = train_returning_params(&cfg, &compute, &ds, &graph, &w).unwrap();
        // evaluating the replayed θ reproduces the last logged loss exactly
        let eval = compute.eval_full(&theta, &ds.shards).unwrap();
        assert_eq!(eval.0, log.rows.last().unwrap().loss);
    }
}
