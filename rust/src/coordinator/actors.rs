//! Actor execution driver: one OS thread per hospital, gossip over the
//! channel netsim — the fidelity path.
//!
//! Every node owns its shard, its parameters, its RNG streams, and its own
//! compute backend (PJRT engines are `Rc`-based and thread-local, so each
//! node thread loads its own engine and compiles only the artifacts the node
//! needs).  Nothing central ever touches parameters except the metrics
//! evaluator, which receives read-only snapshots — the leader is an
//! *observer*, not a fusion center; training would proceed identically
//! without it (the paper's premise).
//!
//! Per communication round each node: runs Q−1 eq.-4 local steps, derives
//! the round's network view from the shared `(seed, round)`-keyed
//! [`NetworkSchedule`], broadcasts θ (and ϑ for DSGT) to that round's
//! *active* neighbors, gathers the neighborhood, applies the eq.-2/3 update
//! through the degree-sparse `combine` kernel with the round's `(neighbor,
//! weight)` row (bitwise-equal to the dense row, §Perf), and advances its
//! causal clock.  When `comm.compress` is configured the node encodes its
//! payloads under the `(seed, round, node, kind)` key before broadcasting,
//! puts the *encoded* message on the wire (charged at its true size), keeps
//! the decoded x̂ for its own mixing row, and applies the difference-form
//! update — mix decoded values, add back its own full-precision correction
//! (DESIGN.md §10) — with the opt-in EF residual compensating the outgoing
//! message when enabled.  Every step uses the same helpers, in the same
//! order, as the fused driver's whole-stack pass, so compressed
//! trajectories stay bitwise-equal across drivers.  Channels are wired
//! over the schedule's union graph (a
//! superset of any round's edges), so a time-varying plan only changes who
//! a node talks to, never the plumbing.  A node that the churn plan takes
//! offline draws-and-discards its communication batch (keeping the sampler
//! stream aligned across drivers and plans, §7) and skips the exchange.
//! Byte/latency accounting comes from the netsim itself.
//! Under a heterogeneous compute plan (`engine::stragglers`) each node
//! derives its own `(seed, round, node)`-keyed τ_i, runs only its first
//! τ_i − 1 local steps (batches beyond that are drawn but unused, keeping
//! the sampler streams plan-independent), rescales its displacement by the
//! shared FedNova-style τ-weight, and advances its causal clock at its own
//! speed — the gossip gather then makes every round as slow as its slowest
//! participant, which is exactly what the fused driver's analytic
//! accountant charges.
//!
//! Each node caches its slice of the view under the schedule's view key
//! (once for static, once per epoch for rewire).  Edge-drop/churn views
//! change every round, so every node rederives them independently — that
//! per-node O(n²) is the price of coordination-free determinism (no shared
//! mutable cache between node threads), and it is deliberate: the fused
//! driver is the throughput path, actors are the fidelity path.
//!
//! The round structure is NOT duplicated here: each node thread implements
//! [`engine::Driver`] and runs the same [`engine::RoundEngine`] loop as the
//! fused path — only the phase bodies (netsim gossip instead of one fused
//! whole-network call) differ, which is exactly what pins driver
//! equivalence, for static and dynamic network plans alike.

use crate::algo::{add_diff, axpy, scale_displacement};
use crate::algo::native::NativeModel;
use crate::compress::GossipComm;
use crate::config::ExperimentConfig;
use crate::data::{FederatedDataset, Shard};
use crate::engine::pipeline::{encode_row_owned, RowPerturb};
use crate::engine::{self, ComputeSchedule, RoundEngine};
use crate::graph::{Graph, NetworkSchedule, ViewScratch};
use crate::metrics::{round_metrics, RunLog};
use crate::mixing::SparseW;
use crate::netsim::{self, LinkModel, Payload, PayloadKind};
use anyhow::{anyhow, bail, Context, Result};
use std::sync::mpsc::channel;
use std::sync::Arc;

use super::compute::Compute;
use super::sampler::{init_theta, init_thetas, NodeSampler};


/// Snapshot a node sends the observer every `eval_every` rounds.
struct Snapshot {
    round: u64,
    node: usize,
    theta: Vec<f32>,
}


/// One node's training task (everything thread-local; the network schedule
/// is shared read-only — every node derives identical per-round views).
struct NodeTask {
    id: usize,
    shard: Shard,
    net: Arc<NetworkSchedule>,
    use_tracker: bool,
    cfg: ExperimentConfig,
}

impl NodeTask {
    fn run(
        &self,
        compute: &dyn Compute,
        ep: netsim::Endpoint,
        tx: std::sync::mpsc::Sender<Snapshot>,
    ) -> Result<Vec<f32>> {
        let (d, h, p) = compute.dims();
        let model = NativeModel::new(d, h);
        let eng = RoundEngine::from_config(&self.cfg);
        let local = eng.plan.local_per_round;
        let m = self.cfg.m;
        let n = self.net.n();

        // gossip-compression context: identical derivation to the fused
        // driver's strategies, so both sides key the same message streams
        let mut comm = GossipComm::from_config(&self.cfg)?;
        // adversarial/DP perturbation lives at the encode boundary, so a
        // perturbed run with no compressor configured routes through
        // `Identity` — same dense bytes on the wire, same decoded values,
        // and the same routing decision the fused driver makes
        let perturb = engine::MsgPerturb::from_config(&self.cfg)?;
        if perturb.is_some() && comm.comp.is_none() {
            comm.comp = Some(Box::new(crate::compress::Identity));
        }
        let compressing = comm.enabled();
        let ef = compressing && comm.error_feedback;
        let tracked = self.use_tracker;
        // per-node local-work schedule — every node derives the identical
        // (seed, round, node)-keyed plan, exactly like the network schedule
        let csched = ComputeSchedule::from_config(&self.cfg)?;

        let mut driver = NodeDriver {
            task: self,
            compute,
            ep,
            tx,
            p,
            theta: init_theta(self.cfg.seed, self.id, &model),
            y_tr: Vec::new(),
            g_prev: Vec::new(),
            sampler: NodeSampler::new(self.cfg.seed, self.id, m),
            lx: vec![0.0f32; local * m * d],
            ly: vec![0.0f32; local * m],
            bx: vec![0.0f32; m * d],
            by: vec![0.0f32; m],
            stacked: vec![0.0f32; n * p],
            comm,
            e_theta: vec![0.0f32; if ef { p } else { 0 }],
            e_y: vec![0.0f32; if ef && tracked { p } else { 0 }],
            vbuf: vec![0.0f32; if compressing { p } else { 0 }],
            xhat_own: vec![0.0f32; if compressing { p } else { 0 }],
            yhat_own: vec![0.0f32; if compressing && tracked { p } else { 0 }],
            perturb,
            csched,
            net_key: None,
            scratch: ViewScratch::new(),
            online_now: true,
            nbrs: Vec::new(),
            widx: Vec::new(),
            wval: Vec::new(),
        };
        eng.run(&mut driver)?;
        Ok(driver.theta)
    }
}

/// Per-node [`engine::Driver`]: the same round loop as the fused path, with
/// the communication phase realized as real gossip over the channel netsim.
struct NodeDriver<'a> {
    task: &'a NodeTask,
    compute: &'a dyn Compute,
    ep: netsim::Endpoint,
    tx: std::sync::mpsc::Sender<Snapshot>,
    p: usize,
    theta: Vec<f32>,
    /// DSGT tracker ϑ and previous gradient (empty for DSGD).
    y_tr: Vec<f32>,
    g_prev: Vec<f32>,
    sampler: NodeSampler,
    lx: Vec<f32>,
    ly: Vec<f32>,
    bx: Vec<f32>,
    by: Vec<f32>,
    stacked: Vec<f32>,
    /// Gossip-compression context (compressor + EF toggle + seed).
    comm: GossipComm,
    /// Attack/DP perturbation pipeline (`engine::adversary`), applied to
    /// this node's outgoing messages at the encode boundary — `None` on the
    /// pinned honest path.
    perturb: Option<engine::MsgPerturb>,
    /// Per-round local-work schedule (`engine::stragglers`); uniform plans
    /// keep the legacy phase bodies byte for byte.
    csched: ComputeSchedule,
    /// Error-feedback residuals for the θ / tracker streams (empty unless
    /// compressing with EF).
    e_theta: Vec<f32>,
    e_y: Vec<f32>,
    /// Encode scratch `[p]`: the error-compensated message v = x + e.
    vbuf: Vec<f32>,
    /// This node's own decoded x̂ / ŷ rows — what it contributes to its own
    /// mix, matching what its neighbors decode from the wire.
    xhat_own: Vec<f32>,
    yhat_own: Vec<f32>,
    /// Cached slice of the current round's network view (own online flag,
    /// active neighbors, degree-sparse W row), refreshed when the schedule's
    /// view key changes — built once for static plans, once per epoch for
    /// rewire.
    net_key: Option<u64>,
    /// Grow-only workspace per-round views materialize into.  Per-node
    /// scratch is O(base nnz) each — the actor driver is the small-n
    /// fidelity path, so n copies are cheap; the fused driver holds one.
    scratch: ViewScratch,
    online_now: bool,
    nbrs: Vec<usize>,
    /// This round's gossip row as `(neighbor, weight)` pairs, ascending,
    /// nonzeros only (self included) — combining over it is bitwise-equal to
    /// the dense row while touching only `deg + 1` stack rows.
    widx: Vec<u32>,
    wval: Vec<f32>,
}

impl NodeDriver<'_> {
    /// Refresh the cached network view for `round` (no-op while the
    /// schedule's view key is unchanged — mirrors `SyncDriver::refresh_net`).
    fn refresh_net(&mut self, round: usize) -> Result<()> {
        let key = self.task.net.view_key(round);
        if self.net_key == Some(key) {
            return Ok(());
        }
        let view = self.task.net.view_into(round, &mut self.scratch)?;
        let id = self.task.id;
        self.online_now = view.online[id];
        view.active_neighbors_into(id, &mut self.nbrs);
        // copy the borrowed CSR row into the node's cache (the scratch is
        // overwritten by the next refresh); grow-only, so warm refreshes
        // into same-or-smaller rows never allocate
        let (widx, wval) = view.sparse_row(id);
        self.widx.clear();
        self.widx.extend_from_slice(widx);
        self.wval.clear();
        self.wval.extend_from_slice(wval);
        self.net_key = Some(key);
        Ok(())
    }
}

/// One payload stream's encode-and-broadcast step of a compressed round:
/// run the shared message pipeline ([`engine::pipeline::encode_row_owned`]
/// — EF compensation, the attack/DP stage at the encode boundary, the
/// deterministic encode under the `(seed, round, node, kind)` key, the
/// decoded x̂ kept in `hat` as the node's own mix row, the residual update)
/// and put the *encoded* message on the wire.  The per-stream twin of the
/// fused driver's `ef_compress_stack` row step — both ARE the same
/// pipeline function, which is what keeps DSGD's and DSGT's streams from
/// ever diverging between drivers.
#[allow(clippy::too_many_arguments)]
fn ef_encode_send(
    comp: &dyn crate::compress::Compressor,
    ef: bool,
    seed: u64,
    round: usize,
    id: usize,
    kind: PayloadKind,
    data: &[f32],
    e: &mut [f32],
    vbuf: &mut [f32],
    hat: &mut [f32],
    ep: &mut netsim::Endpoint,
    nbrs: &[usize],
    perturb: Option<&mut engine::MsgPerturb>,
) -> Result<()> {
    let rp = match perturb {
        Some(pb) => RowPerturb::Inline(pb),
        None => RowPerturb::Off,
    };
    let enc = encode_row_owned(comp, ef, seed, round, id, kind, data, e, vbuf, hat, rp)?;
    ep.send_to(nbrs, round as u64, kind, &Arc::new(Payload::Compressed(enc)))?;
    Ok(())
}

impl engine::Driver for NodeDriver<'_> {
    fn begin(&mut self) -> Result<()> {
        // DSGT init: Y⁰ = G⁰ = ∇g(θ⁰) on a fresh batch.  Round-0 metrics are
        // the observer's job — the node only trains.
        if self.task.use_tracker {
            self.sampler.batch(&self.task.shard, &mut self.bx, &mut self.by);
            let (_, g0) = self.compute.grad_step(&self.theta, &self.bx, &self.by)?;
            self.y_tr = g0.clone();
            self.g_prev = g0;
        }
        Ok(())
    }

    fn local_phase(&mut self, round: usize, lrs: &[f32]) -> Result<()> {
        // full Q−1 batches drawn whatever the compute plan — stragglers use
        // only their prefix, keeping sampler streams plan-independent (§7)
        self.sampler.batches(&self.task.shard, lrs.len(), &mut self.lx, &mut self.ly);
        if self.csched.is_uniform() {
            let (t2, _) = self.compute.local_steps(&self.theta, &self.lx, &self.ly, lrs)?;
            self.theta = t2;
            self.ep.spend_compute(lrs.len() as f64 * self.task.cfg.compute_s_per_step);
            return Ok(());
        }
        // straggler round: τ_i − 1 truncated local steps on the batch
        // prefix, then the FedNova-style τ-weighted displacement rescale —
        // the per-node twin of the fused driver's whole-stack pass, using
        // the same kernels and the same schedule-derived weight, so the
        // drivers stay bitwise-equal
        let id = self.task.id;
        let (d, _, _) = self.compute.dims();
        let m = self.task.cfg.m;
        let li = (self.csched.tau(round, id) - 1).min(lrs.len());
        if li > 0 {
            let (t2, _) = self.compute.local_steps(
                &self.theta,
                &self.lx[..li * m * d],
                &self.ly[..li * m],
                &lrs[..li],
            )?;
            let w = self.csched.tau_weight(round, id);
            if w != 1.0 {
                let prev = std::mem::replace(&mut self.theta, t2);
                scale_displacement(&mut self.theta, &prev, w);
            } else {
                self.theta = t2;
            }
        }
        // this node's own clock runs at its own speed — the causal clocks
        // make the round complete when the slowest participant arrives
        self.ep.spend_compute(
            li as f64 * self.task.cfg.compute_s_per_step / self.csched.speed(round, id),
        );
        Ok(())
    }

    fn comm_phase(&mut self, round: usize, lr: f32) -> Result<()> {
        let p = self.p;
        let id = self.task.id;
        self.refresh_net(round)?;
        if !self.online_now {
            // Offline this round (node churn): draw-and-discard the
            // communication batch so the (seed, row)-keyed sampler stream
            // stays aligned across drivers and plans (§7), then skip the
            // exchange — θ (and ϑ, G) stay untouched, mirroring the fused
            // driver's offline-row restore bit for bit.
            self.sampler.batch(&self.task.shard, &mut self.bx, &mut self.by);
            return Ok(());
        }

        // ---- gossip exchange over this round's active edges ----
        let round_tag = round as u64;
        let compressing = self.comm.enabled();
        if let Some(comp) = &self.comm.comp {
            let ef = self.comm.error_feedback;
            ef_encode_send(
                comp.as_ref(),
                ef,
                self.comm.seed,
                round,
                id,
                PayloadKind::Params,
                &self.theta,
                &mut self.e_theta,
                &mut self.vbuf,
                &mut self.xhat_own,
                &mut self.ep,
                &self.nbrs,
                self.perturb.as_mut(),
            )?;
            if self.task.use_tracker {
                ef_encode_send(
                    comp.as_ref(),
                    ef,
                    self.comm.seed,
                    round,
                    id,
                    PayloadKind::Tracker,
                    &self.y_tr,
                    &mut self.e_y,
                    &mut self.vbuf,
                    &mut self.yhat_own,
                    &mut self.ep,
                    &self.nbrs,
                    self.perturb.as_mut(),
                )?;
            }
        } else {
            // the perturbation pipeline requires the encode path; run()
            // installs an Identity compressor whenever one is active, so an
            // unperturbed dense broadcast is the only way to reach here
            anyhow::ensure!(
                self.perturb.is_none(),
                "perturbation pipeline active without a compressor — node {id} misrouted",
            );
            let payload = Arc::new(Payload::Dense(self.theta.clone()));
            self.ep.send_to(&self.nbrs, round_tag, PayloadKind::Params, &payload)?;
            if self.task.use_tracker {
                let tp = Arc::new(Payload::Dense(self.y_tr.clone()));
                self.ep.send_to(&self.nbrs, round_tag, PayloadKind::Tracker, &tp)?;
            }
        }

        // The sparse combine reads only the rows named in `widx` — self plus
        // this round's active neighbors, every one of which is overwritten
        // below before combining — so the stack is never re-zeroed; stale
        // rows from earlier rounds are unreachable by construction.
        let got = self.ep.gather_from(&self.nbrs, round_tag, PayloadKind::Params)?;
        // DSGT's quarantine is kind-coupled — a sender non-finite in either
        // stream is folded out of both mixes — so the tracker gather happens
        // before the first combine (nothing between the two gathers touches
        // the simulated clock, so honest rounds are unaffected).
        let got_y = if self.task.use_tracker {
            self.ep.gather_from(&self.nbrs, round_tag, PayloadKind::Tracker)?
        } else {
            Vec::new()
        };

        // ---- non-finite ingest guard (DESIGN.md §14) ----
        // Classify each neighbor payload before anything is mixed; a bad
        // sender's weight folds into the self-weight.  `bad` stays empty —
        // and nothing below allocates — on the honest path.
        let mut bad: Vec<usize> = Vec::new();
        for (from, pl) in got.iter().chain(got_y.iter()) {
            if !pl.is_finite() && !bad.contains(from) {
                bad.push(*from);
            }
        }
        let mut qidx: Vec<u32> = Vec::new();
        let mut qval: Vec<f32> = Vec::new();
        let (widx, wval): (&[u32], &[f32]) = if bad.is_empty() {
            (&self.widx, &self.wval)
        } else {
            // Fold the quarantined neighbors' weights into the self-weight
            // in CSR (ascending-column) order, materializing a missing
            // diagonal and dropping exact-zero entries — the identical
            // arithmetic, in the identical order, as the fused driver's
            // `quarantine_compact`, so the fused==actors bitwise pin
            // survives an active quarantine.
            let mut folded = 0.0f32;
            let mut dropped = 0u64;
            for (&j, &v) in self.widx.iter().zip(&self.wval) {
                if j as usize != id && bad.contains(&(j as usize)) {
                    folded += v;
                    dropped += 1;
                }
            }
            let mut push = |j: u32, v: f32| {
                if v != 0.0 {
                    qidx.push(j);
                    qval.push(v);
                }
            };
            let mut diag_done = false;
            for (&j, &v) in self.widx.iter().zip(&self.wval) {
                let ju = j as usize;
                if !diag_done && ju > id {
                    push(id as u32, folded);
                    diag_done = true;
                }
                if ju == id {
                    push(j, v + folded);
                    diag_done = true;
                } else if !bad.contains(&ju) {
                    push(j, v);
                }
            }
            if !diag_done {
                push(id as u32, folded);
            }
            self.ep.report_quarantine(dropped);
            (&qidx, &qval)
        };

        // Own mix row: the decoded x̂ under compression — exactly what the
        // neighbors decode from the wire — the true θ otherwise.
        if compressing {
            self.stacked[id * p..(id + 1) * p].copy_from_slice(&self.xhat_own);
        } else {
            self.stacked[id * p..(id + 1) * p].copy_from_slice(&self.theta);
        }
        for (from, pl) in &got {
            pl.decode_into(&mut self.stacked[from * p..(from + 1) * p])?;
        }
        let mixed = self.compute.combine_sparse(id as u32, widx, wval, &self.stacked)?;

        // ---- eq. 2 / eq. 3 update ----
        // Byzantine nodes broadcast poison but don't follow the update
        // rule: an attacker computes the round like everyone else (keeping
        // the sampler and compressor streams aligned across drivers) and
        // then discards the result, ending the round at its post-local
        // state — the actors-side image of the fused driver's
        // `restore_attacker_rows`.
        let byzantine = self
            .perturb
            .as_ref()
            .is_some_and(|pb| pb.attack.active() && pb.attack.is_attacker(id));
        self.sampler.batch(&self.task.shard, &mut self.bx, &mut self.by);
        if self.task.use_tracker {
            if compressing {
                self.stacked[id * p..(id + 1) * p].copy_from_slice(&self.yhat_own);
            } else {
                self.stacked[id * p..(id + 1) * p].copy_from_slice(&self.y_tr);
            }
            for (from, pl) in &got_y {
                pl.decode_into(&mut self.stacked[from * p..(from + 1) * p])?;
            }
            let mixed_y = self.compute.combine_sparse(id as u32, widx, wval, &self.stacked)?;
            // θ^{r+1} = Σ W θ̂ (+ own full-precision correction under
            // compression, DESIGN.md §10) − α ϑ_i (own tracker)
            let mut theta_next = mixed;
            if compressing {
                add_diff(&mut theta_next, &self.theta, &self.xhat_own);
            }
            axpy(&mut theta_next, -lr, &self.y_tr);
            // ϑ^{r+1} = Σ W ϑ̂ (+ correction) + ∇g(θ^{r+1}) − ∇g(θ^r)
            let (_, g_new) = self.compute.grad_step(&theta_next, &self.bx, &self.by)?;
            let mut y_next = mixed_y;
            if compressing {
                add_diff(&mut y_next, &self.y_tr, &self.yhat_own);
            }
            axpy(&mut y_next, 1.0, &g_new);
            axpy(&mut y_next, -1.0, &self.g_prev);
            if !byzantine {
                self.theta = theta_next;
                self.y_tr = y_next;
                self.g_prev = g_new;
            }
        } else {
            // θ^{r+1} = Σ W θ̂ (+ correction) − α ∇g(θ^r): gradient at
            // pre-mix θ
            let (_, grad) = self.compute.grad_step(&self.theta, &self.bx, &self.by)?;
            let mut theta_next = mixed;
            if compressing {
                add_diff(&mut theta_next, &self.theta, &self.xhat_own);
            }
            axpy(&mut theta_next, -lr, &grad);
            if !byzantine {
                self.theta = theta_next;
            }
        }
        // the communication gradient runs at this node's round speed too
        let s = self.task.cfg.compute_s_per_step;
        if self.csched.is_uniform() {
            self.ep.spend_compute(s);
        } else {
            self.ep.spend_compute(s / self.csched.speed(round, self.task.id));
        }
        Ok(())
    }

    fn observe(&mut self, round: u64, _local_steps: u64) -> Result<()> {
        self.tx
            .send(Snapshot { round, node: self.task.id, theta: self.theta.clone() })
            .map_err(|_| anyhow!("observer hung up"))
    }
}

/// Train with the actor driver.  `make_compute` is invoked once inside each
/// node thread; `eval_compute` is the observer's backend for metrics.
pub fn train<F>(
    cfg: &ExperimentConfig,
    make_compute: &F,
    eval_compute: &dyn Compute,
    ds: &FederatedDataset,
    graph: &Graph,
    w: &SparseW,
) -> Result<RunLog>
where
    F: Fn(usize) -> Result<Box<dyn Compute>> + Sync,
{
    let n = ds.n_hospitals();
    if graph.n() != n {
        bail!("graph has {} nodes, dataset has {n}", graph.n());
    }
    // every node thread derives the identical round schedule from the same
    // config, and the identical per-round network views from the shared
    // (seed, round)-keyed schedule
    let eng = RoundEngine::from_config(cfg);
    let q = eng.q;
    let csched = ComputeSchedule::from_config(cfg)?;
    // the observer mirrors the fused driver's (ε, δ) accounting: one DP
    // release per payload kind per communication round (an upper bound
    // under churn — offline rounds release nothing)
    let dp = engine::adversary::dp_from_config(cfg)?;
    let dp_kinds: u64 = if cfg.algo.uses_tracker() { 2 } else { 1 };
    // under an active attack the observer reports honest-sub-fleet metrics
    // (engine::pipeline::eval_honest_subset, DESIGN.md §14), same as fused
    let attack = engine::adversary::AttackSchedule::from_config(cfg)?;
    csched.ensure_runnable(n, eval_compute.local_steps_len())?;
    let net = Arc::new(NetworkSchedule::from_config(cfg, graph.clone(), w.clone())?);
    // channels are wired over the union of every round's gossip graph
    let union = net.union_graph(eng.rounds)?;
    let link = LinkModel {
        latency_s: cfg.latency_s,
        bandwidth_bps: cfg.bandwidth_bps,
        drop_prob: cfg.drop_prob,
    };
    let (endpoints, stats) = netsim::build(&union, link, cfg.seed);
    let (snap_tx, snap_rx) = channel::<Snapshot>();
    let started = std::time::Instant::now();

    let tasks: Vec<(NodeTask, netsim::Endpoint)> = endpoints
        .into_iter()
        .enumerate()
        .map(|(i, ep)| {
            (
                NodeTask {
                    id: i,
                    shard: ds.shards[i].clone(),
                    net: Arc::clone(&net),
                    use_tracker: cfg.algo.uses_tracker(),
                    cfg: cfg.clone(),
                },
                ep,
            )
        })
        .collect();

    std::thread::scope(|scope| -> Result<RunLog> {
        let mut joins = Vec::with_capacity(n);
        for (task, ep) in tasks {
            let tx = snap_tx.clone();
            joins.push(scope.spawn(move || -> Result<Vec<f32>> {
                let compute = make_compute(task.id)
                    .with_context(|| format!("building compute for node {}", task.id))?;
                task.run(compute.as_ref(), ep, tx)
            }));
        }
        drop(snap_tx);

        // observer loop
        let (d_e, h_e, p) = eval_compute.dims();
        let model = NativeModel::new(d_e, h_e);
        let theta0 = init_thetas(cfg.seed, n, &model);
        let mut log = RunLog::new(cfg.algo.name());
        let eval0 =
            engine::pipeline::eval_honest_subset(Some(&attack), &theta0, &ds.shards, p, eval_compute)?;
        log.push(round_metrics(0, 0, eval0, stats.snapshot(), started.elapsed().as_secs_f64()));

        let mut pending: std::collections::BTreeMap<u64, (usize, Vec<f32>)> = Default::default();
        // true local-work counter for heterogeneous plans: Σ_r Σ_i τ_i(r),
        // accumulated over every round up to the observed one (rounds
        // complete in order — each node snapshots in round order)
        let (mut work, mut work_round) = (0u64, 0u64);
        while let Ok(snap) = snap_rx.recv() {
            let entry = pending
                .entry(snap.round)
                .or_insert_with(|| (0, vec![0.0f32; n * p]));
            entry.1[snap.node * p..(snap.node + 1) * p].copy_from_slice(&snap.theta);
            entry.0 += 1;
            if entry.0 == n {
                let (_, stacked) = pending.remove(&snap.round).unwrap();
                stats.rounds.store(snap.round, std::sync::atomic::Ordering::Relaxed);
                let eval = engine::pipeline::eval_honest_subset(
                    Some(&attack),
                    &stacked,
                    &ds.shards,
                    p,
                    eval_compute,
                )?;
                let steps = if csched.is_uniform() {
                    snap.round * q as u64
                } else {
                    while work_round < snap.round {
                        work_round += 1;
                        work += csched.local_work(work_round as usize);
                    }
                    work / n as u64
                };
                let mut row = round_metrics(
                    snap.round,
                    steps,
                    eval,
                    stats.snapshot(),
                    started.elapsed().as_secs_f64(),
                );
                row.dp_epsilon = dp.epsilon(dp_kinds * snap.round);
                log.push(row);
            }
        }

        for j in joins {
            j.join().map_err(|_| anyhow!("node thread panicked"))??;
        }
        Ok(log)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AlgoKind, Backend, ExperimentConfig, Mode};
    use crate::coordinator::compute::NativeCompute;
    use crate::data::{generate, DataConfig};
    use crate::graph::Topology;
    use crate::mixing::{build_sparse, Scheme};
    use crate::rng::Pcg64;

    fn setup(
        algo: AlgoKind,
        q: usize,
        steps: usize,
    ) -> (ExperimentConfig, FederatedDataset, Graph, SparseW) {
        let mut cfg = ExperimentConfig::default();
        cfg.n = 4;
        cfg.hidden = 8;
        cfg.m = 8;
        cfg.q = q;
        cfg.algo = algo;
        cfg.total_steps = steps;
        cfg.eval_every = 2;
        cfg.mode = Mode::Actors;
        cfg.backend = Backend::Native;
        cfg.records_per_hospital = 60;
        let ds = generate(&DataConfig {
            n_hospitals: cfg.n,
            records_per_hospital: 60,
            records_jitter: 0,
            heterogeneity: 0.5,
            ..DataConfig::default()
        })
        .unwrap();
        let graph = Graph::build(&Topology::Ring, cfg.n, &mut Pcg64::seed(1)).unwrap();
        let w = build_sparse(&graph, Scheme::Metropolis);
        (cfg, ds, graph, w)
    }

    fn native_factory(cfg: &ExperimentConfig) -> impl Fn(usize) -> Result<Box<dyn Compute>> + Sync {
        let (d, h, n, m) = (cfg.d, cfg.hidden, cfg.n, cfg.m);
        move |_node| Ok(Box::new(NativeCompute::new(d, h, n, m)) as Box<dyn Compute>)
    }

    #[test]
    fn actor_dsgd_trains() {
        let (cfg, ds, graph, w) = setup(AlgoKind::Dsgd, 1, 150);
        let eval = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m);
        let factory = native_factory(&cfg);
        let log = train(&cfg, &factory, &eval, &ds, &graph, &w).unwrap();
        assert!(log.rows.len() >= 2);
        let first = log.rows.first().unwrap().loss;
        let last = log.rows.last().unwrap().loss;
        assert!(last < first, "loss {first} -> {last}");
        // bytes flowed
        assert!(log.rows.last().unwrap().bytes > 0);
    }

    #[test]
    fn actor_matches_fused_trajectory_native() {
        // identical seeds + native backend on both drivers → identical metrics
        for (algo, q, steps) in [
            (AlgoKind::Dsgd, 1, 12),
            (AlgoKind::FdDsgd, 4, 24),
            (AlgoKind::Dsgt, 1, 12),
            (AlgoKind::FdDsgt, 4, 24),
        ] {
            let (mut cfg, ds, graph, w) = setup(algo, q, steps);
            cfg.eval_every = 1;
            let eval = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m);
            let factory = native_factory(&cfg);
            let log_a = train(&cfg, &factory, &eval, &ds, &graph, &w).unwrap();
            let log_f = crate::coordinator::fused::train(&cfg, &eval, &ds, &graph, &w).unwrap();
            assert_eq!(log_a.rows.len(), log_f.rows.len(), "{algo:?}");
            for (ra, rf) in log_a.rows.iter().zip(&log_f.rows) {
                assert_eq!(ra.comm_rounds, rf.comm_rounds, "{algo:?}");
                assert!(
                    (ra.loss - rf.loss).abs() < 1e-9,
                    "{algo:?} round {}: {} vs {}",
                    ra.comm_rounds,
                    ra.loss,
                    rf.loss
                );
                assert!((ra.consensus - rf.consensus).abs() < 1e-9, "{algo:?}");
            }
            // byte accounting agrees between channel netsim and analytic model
            let ba = log_a.rows.last().unwrap().bytes;
            let bf = log_f.rows.last().unwrap().bytes;
            assert_eq!(ba, bf, "{algo:?} actor bytes {ba} vs fused bytes {bf}");
        }
    }

    #[test]
    fn actor_dynamic_plans_train_over_real_channels() {
        for (plan, steps) in [("rewire", 24), ("edge-drop", 24), ("churn", 36)] {
            let (mut cfg, ds, graph, w) = setup(AlgoKind::FdDsgd, 3, steps);
            cfg.net_plan = plan.into();
            cfg.rewire_every = 2;
            cfg.edge_drop = 0.3;
            cfg.churn = 0.3;
            let eval = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m);
            let factory = native_factory(&cfg);
            let log = train(&cfg, &factory, &eval, &ds, &graph, &w).unwrap();
            let first = log.rows.first().unwrap().loss;
            let last = log.rows.last().unwrap().loss;
            assert!(last < first, "{plan}: loss {first} -> {last}");
            assert!(log.rows.last().unwrap().bytes > 0, "{plan}");
        }
    }

    #[test]
    fn actor_churn_sends_fewer_bytes_than_static() {
        let (mut cfg, ds, graph, w) = setup(AlgoKind::FdDsgd, 3, 36);
        cfg.net_plan = "churn".into();
        cfg.churn = 0.3;
        let eval = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m);
        let factory = native_factory(&cfg);
        let churn_log = train(&cfg, &factory, &eval, &ds, &graph, &w).unwrap();
        let (cfg2, ds2, graph2, w2) = setup(AlgoKind::FdDsgd, 3, 36);
        let factory2 = native_factory(&cfg2);
        let static_log = train(&cfg2, &factory2, &eval, &ds2, &graph2, &w2).unwrap();
        // offline rounds silence their node's links
        assert!(
            churn_log.rows.last().unwrap().bytes < static_log.rows.last().unwrap().bytes,
            "churn {} vs static {}",
            churn_log.rows.last().unwrap().bytes,
            static_log.rows.last().unwrap().bytes
        );
    }

    #[test]
    fn actor_straggler_plans_train_over_real_channels() {
        for plan in ["fixed-tiers", "dropout"] {
            let (mut cfg, ds, graph, w) = setup(AlgoKind::FdDsgd, 4, 32);
            cfg.compute_plan = plan.into();
            cfg.compute_tiers = "1.0,0.5".into();
            cfg.slow_frac = 0.4;
            let eval = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m);
            let factory = native_factory(&cfg);
            let log = train(&cfg, &factory, &eval, &ds, &graph, &w).unwrap();
            let first = log.rows.first().unwrap().loss;
            let last = log.rows.last().unwrap().loss;
            assert!(last < first, "{plan}: loss {first} -> {last}");
            // straggler rounds report their true (reduced) local work
            let final_row = log.rows.last().unwrap();
            assert!(
                final_row.local_steps <= final_row.comm_rounds * cfg.q as u64,
                "{plan}"
            );
        }
    }

    #[test]
    fn actor_matches_fused_under_attack_and_dp() {
        // the adversarial encode boundary must not break driver equivalence:
        // attacked and DP'd runs stay trajectory-identical between the
        // actor and fused drivers, and their (ε, δ) accounting agrees bitwise
        for (algo, plan, dp) in [
            (AlgoKind::Dsgd, "sign-flip", "off"),
            (AlgoKind::Dsgt, "sign-flip", "off"),
            (AlgoKind::Dsgd, "stale-replay", "off"),
            (AlgoKind::Dsgd, "none", "gaussian"),
        ] {
            let (mut cfg, ds, graph, w) = setup(algo, 1, 10);
            cfg.eval_every = 1;
            cfg.attack_plan = plan.into();
            cfg.attack_frac = 0.25;
            cfg.dp = dp.into();
            cfg.dp_clip = 50.0;
            let eval = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m);
            let factory = native_factory(&cfg);
            let log_a = train(&cfg, &factory, &eval, &ds, &graph, &w).unwrap();
            let log_f = crate::coordinator::fused::train(&cfg, &eval, &ds, &graph, &w).unwrap();
            assert_eq!(log_a.rows.len(), log_f.rows.len(), "{algo:?}/{plan}/{dp}");
            for (ra, rf) in log_a.rows.iter().zip(&log_f.rows) {
                assert!(
                    (ra.loss - rf.loss).abs() < 1e-9,
                    "{algo:?}/{plan}/{dp} round {}: {} vs {}",
                    ra.comm_rounds,
                    ra.loss,
                    rf.loss
                );
                assert!((ra.consensus - rf.consensus).abs() < 1e-9, "{algo:?}/{plan}/{dp}");
                assert_eq!(
                    ra.dp_epsilon.to_bits(),
                    rf.dp_epsilon.to_bits(),
                    "{algo:?}/{plan}/{dp} ε accounting must agree bitwise"
                );
            }
            let (ba, bf) = (log_a.rows.last().unwrap().bytes, log_f.rows.last().unwrap().bytes);
            assert_eq!(ba, bf, "{algo:?}/{plan}/{dp} byte accounting");
            if dp == "gaussian" {
                assert!(log_a.rows.last().unwrap().dp_epsilon > 0.0);
            }
        }
    }

    #[test]
    fn actor_quarantine_matches_fused() {
        // an attack hot enough to overflow f32 produces non-finite payloads;
        // both drivers must fold the attacker out (same arithmetic, same
        // order) and report the same quarantine counts
        for algo in [AlgoKind::Dsgd, AlgoKind::Dsgt] {
            let (mut cfg, ds, graph, w) = setup(algo, 1, 8);
            cfg.eval_every = 1;
            cfg.attack_plan = "scaled-noise".into();
            cfg.attack_frac = 0.25;
            cfg.attack_scale = 1e39; // overflows f32 → Inf on the wire
            let eval = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m);
            let factory = native_factory(&cfg);
            let log_a = train(&cfg, &factory, &eval, &ds, &graph, &w).unwrap();
            let log_f = crate::coordinator::fused::train(&cfg, &eval, &ds, &graph, &w).unwrap();
            let (qa, qf) = (
                log_a.rows.last().unwrap().quarantined,
                log_f.rows.last().unwrap().quarantined,
            );
            assert!(qa > 0, "{algo:?}: the poisoned payloads must be quarantined");
            assert_eq!(qa, qf, "{algo:?}: quarantine counts must agree across drivers");
            // the quarantined trajectories agree too (NaN-safe: compare bits
            // of the consensus, which stays finite for honest majorities)
            for (ra, rf) in log_a.rows.iter().zip(&log_f.rows) {
                let (ca, cf) = (ra.consensus, rf.consensus);
                assert!(
                    (ca.is_nan() && cf.is_nan()) || (ca - cf).abs() < 1e-9,
                    "{algo:?} round {}: consensus {ca} vs {cf}",
                    ra.comm_rounds
                );
            }
        }
    }

    #[test]
    fn actor_survives_lossy_links() {
        let (mut cfg, ds, graph, w) = setup(AlgoKind::FdDsgt, 3, 12);
        cfg.drop_prob = 0.2;
        let eval = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m);
        let factory = native_factory(&cfg);
        let log = train(&cfg, &factory, &eval, &ds, &graph, &w).unwrap();
        // training completed despite drops; retransmissions charged extra bytes
        let lossless = {
            let (cfg2, ds, graph, w) = setup(AlgoKind::FdDsgt, 3, 12);
            let factory = native_factory(&cfg2);
            train(&cfg2, &factory, &eval, &ds, &graph, &w).unwrap()
        };
        assert!(log.rows.last().unwrap().bytes > lossless.rows.last().unwrap().bytes);
        // and the trajectory itself is unaffected (drops are retransmitted)
        assert!((log.rows.last().unwrap().loss - lossless.rows.last().unwrap().loss).abs() < 1e-9);
    }
}
