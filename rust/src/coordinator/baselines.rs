//! Baseline trainers the paper's intro compares against:
//! the *fictitious fusion center* (centralized SGD on pooled data) and
//! star-network FedAvg (McMahan et al., 2017).
//!
//! Both reuse the same artifact-level ops, samplers, lr schedule, and metric
//! shapes as the decentralized drivers, so EXP-A4's comm-cost/quality
//! comparison is apples-to-apples.

use crate::algo::native::NativeModel;
use crate::algo::{axpy, LrSchedule, RoundPlan};
use crate::config::ExperimentConfig;
use crate::data::{FederatedDataset, Shard};
use crate::graph::Graph;
use crate::metrics::{round_metrics, RunLog};
use crate::netsim::{analytic::Accountant, LinkModel, NetSnapshot};
use anyhow::Result;

use super::compute::Compute;
use super::sampler::{init_theta, NodeSampler};

/// Centralized SGD on the pooled cohort — the fusion center the paper argues
/// is infeasible for patient data.  Zero communication by construction; the
/// "comm round" axis advances every Q steps so curves align with FD runs.
pub fn centralized(
    cfg: &ExperimentConfig,
    compute: &dyn Compute,
    ds: &FederatedDataset,
) -> Result<RunLog> {
    let (d, h, _p) = compute.dims();
    let model = NativeModel::new(d, h);
    let pooled = ds.pooled();
    let sched = LrSchedule::new(cfg.alpha0);
    let q = cfg.q.max(1);
    let mut theta = init_theta(cfg.seed, 0, &model);
    let mut sampler = NodeSampler::new(cfg.seed, 0, cfg.m);
    let mut bx = vec![0.0f32; cfg.m * d];
    let mut by = vec![0.0f32; cfg.m];
    let mut log = RunLog::new("centralized");
    let started = std::time::Instant::now();

    let eval_shard = |theta: &[f32]| -> (f64, f64, f64, f64) {
        // single "node" owning everything: consensus ≡ 0
        let (loss, grad) = model.loss_and_grad(theta, &pooled.x, &pooled.y);
        let zs = model.logits(theta, &pooled.x);
        let correct = zs
            .iter()
            .zip(&pooled.y)
            .filter(|(z, &y)| ((**z > 0.0) as u32 as f32) == y)
            .count();
        let stat: f64 = grad.iter().map(|&g| (g as f64) * (g as f64)).sum();
        (loss, correct as f64 / pooled.n as f64, stat, 0.0)
    };

    log.push(round_metrics(0, 0, eval_shard(&theta), NetSnapshot::default(), 0.0));
    for step in 1..=cfg.total_steps {
        sampler.batch(&pooled, &mut bx, &mut by);
        let (_, grad) = compute.grad_step(&theta, &bx, &by)?;
        axpy(&mut theta, -sched.lr(step), &grad);
        if step % (q * cfg.eval_every.max(1)) == 0 || step == cfg.total_steps {
            log.push(round_metrics(
                (step / q) as u64,
                step as u64,
                eval_shard(&theta),
                NetSnapshot::default(),
                started.elapsed().as_secs_f64(),
            ));
        }
    }
    Ok(log)
}

/// Star-network FedAvg: every round each client takes Q local steps from the
/// server parameters, the server averages.  Uses the star graph for comm
/// accounting (client↑ + server↓ per round).
pub fn fedavg(
    cfg: &ExperimentConfig,
    compute: &dyn Compute,
    ds: &FederatedDataset,
) -> Result<RunLog> {
    let n = ds.n_hospitals();
    let (d, h, p) = compute.dims();
    let model = NativeModel::new(d, h);
    let q = cfg.q.max(1);
    let plan = RoundPlan::new(q);
    let rounds = plan.rounds_for(cfg.total_steps);
    let sched = LrSchedule::new(cfg.alpha0);

    // server init = node-0 init (a shared broadcast start, as FedAvg assumes)
    let mut server = init_theta(cfg.seed, 0, &model);
    let mut samplers: Vec<NodeSampler> =
        (0..n).map(|i| NodeSampler::new(cfg.seed, i, cfg.m)).collect();
    let local = plan.local_per_round;
    let mut lx = vec![0.0f32; local * cfg.m * d];
    let mut ly = vec![0.0f32; local * cfg.m];
    let mut bx = vec![0.0f32; cfg.m * d];
    let mut by = vec![0.0f32; cfg.m];

    let star = Graph::build(&crate::graph::Topology::Star, n + 1, &mut crate::rng::Pcg64::seed(0))?;
    let link = LinkModel {
        latency_s: cfg.latency_s,
        bandwidth_bps: cfg.bandwidth_bps,
        drop_prob: 0.0,
    };
    let mut acct = Accountant::new(&star, link);
    let mut log = RunLog::new("fedavg");
    let started = std::time::Instant::now();

    let stacked_server = |server: &[f32]| {
        let mut stacked = Vec::with_capacity(n * p);
        for _ in 0..n {
            stacked.extend_from_slice(server);
        }
        stacked
    };
    let eval0 = compute.eval_full(&stacked_server(&server), &ds.shards)?;
    log.push(round_metrics(0, 0, eval0, acct.snapshot(), 0.0));

    for round in 1..=rounds {
        let mut mean = vec![0.0f64; p];
        for i in 0..n {
            let mut theta = server.clone();
            if local > 0 {
                let lrs = sched.local_lrs(round, q, local);
                samplers[i].batches(&ds.shards[i], local, &mut lx, &mut ly);
                let (t2, _) = compute.local_steps(&theta, &lx, &ly, &lrs)?;
                theta = t2;
            }
            // final local step of the round (keeps total gradient count = Q)
            samplers[i].batch(&ds.shards[i], &mut bx, &mut by);
            let (_, grad) = compute.grad_step(&theta, &bx, &by)?;
            axpy(&mut theta, -sched.comm_lr(round, q), &grad);
            for (acc, &t) in mean.iter_mut().zip(&theta) {
                *acc += t as f64;
            }
        }
        for (s, acc) in server.iter_mut().zip(&mean) {
            *s = (acc / n as f64) as f32;
        }
        acct.local_compute(q as u64, cfg.compute_s_per_step);
        acct.star_round(n, p);

        if round % cfg.eval_every.max(1) == 0 || round == rounds {
            let eval = compute.eval_full(&stacked_server(&server), &ds.shards)?;
            log.push(round_metrics(
                round as u64,
                (round * q) as u64,
                eval,
                acct.snapshot(),
                started.elapsed().as_secs_f64(),
            ));
        }
    }
    Ok(log)
}

/// Test-set AUC for a trained parameter vector (trapezoidal ROC integral) —
/// used by examples to report held-out discrimination.
pub fn auc(compute: &dyn Compute, theta: &[f32], test: &Shard) -> Result<f64> {
    let probs = compute.predict(theta, &test.x)?;
    let mut pairs: Vec<(f32, f32)> = probs.iter().copied().zip(test.y.iter().copied()).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    // rank-sum (Mann–Whitney) AUC with tie handling by average rank
    let n_pos = pairs.iter().filter(|(_, y)| *y == 1.0).count();
    let n_neg = pairs.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return Ok(0.5);
    }
    let mut rank_sum = 0.0f64;
    let mut i = 0usize;
    while i < pairs.len() {
        let mut j = i;
        while j < pairs.len() && pairs[j].0 == pairs[i].0 {
            j += 1;
        }
        let avg_rank = (i + j + 1) as f64 / 2.0; // 1-based average rank
        for k in i..j {
            if pairs[k].1 == 1.0 {
                rank_sum += avg_rank;
            }
        }
        i = j;
    }
    Ok((rank_sum - n_pos as f64 * (n_pos as f64 + 1.0) / 2.0) / (n_pos as f64 * n_neg as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AlgoKind;
    use crate::coordinator::compute::NativeCompute;
    use crate::data::{generate, DataConfig};

    fn setup() -> (ExperimentConfig, NativeCompute, FederatedDataset) {
        let mut cfg = ExperimentConfig::default();
        cfg.n = 4;
        cfg.hidden = 8;
        cfg.m = 10;
        cfg.q = 5;
        cfg.total_steps = 100;
        cfg.eval_every = 2;
        cfg.records_per_hospital = 60;
        let ds = generate(&DataConfig {
            n_hospitals: 4,
            records_per_hospital: 60,
            records_jitter: 0,
            heterogeneity: 0.4,
            ..DataConfig::default()
        })
        .unwrap();
        let compute = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m);
        (cfg, compute, ds)
    }

    #[test]
    fn centralized_trains_with_zero_comm() {
        let (cfg, compute, ds) = setup();
        let log = centralized(&cfg, &compute, &ds).unwrap();
        assert!(log.rows.last().unwrap().loss < log.rows.first().unwrap().loss);
        assert_eq!(log.rows.last().unwrap().bytes, 0);
        assert_eq!(log.rows.last().unwrap().consensus, 0.0);
    }

    #[test]
    fn fedavg_trains_and_pays_star_bytes() {
        let (mut cfg, compute, ds) = setup();
        cfg.algo = AlgoKind::FedAvg;
        let log = fedavg(&cfg, &compute, &ds).unwrap();
        assert!(log.rows.last().unwrap().loss < log.rows.first().unwrap().loss);
        let rounds = log.rows.last().unwrap().comm_rounds;
        let p = compute.dims().2;
        assert_eq!(log.rows.last().unwrap().bytes, rounds * 2 * 4 * (p * 4) as u64);
        // consensus identically zero: all clients leave from server params
        assert_eq!(log.rows.last().unwrap().consensus, 0.0);
    }

    #[test]
    fn auc_on_separable_data_is_high() {
        let (cfg, compute, _) = setup();
        let _ = cfg;
        // fabricate a test shard scored perfectly by construction
        let d = compute.dims().0;
        let model = NativeModel::new(d, compute.dims().1);
        let mut rng = crate::rng::Pcg64::seed(0);
        let theta = model.init(&mut rng);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..50 {
            let row: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let z = model.logits(&theta, &row)[0];
            x.extend_from_slice(&row);
            y.push(if z > 0.0 { 1.0 } else { 0.0 });
            let _ = i;
        }
        let test = Shard { n: 50, d, x, y };
        let a = auc(&compute, &theta, &test).unwrap();
        assert!(a > 0.99, "auc {a}");
    }

    #[test]
    fn auc_of_random_scores_near_half() {
        let (_, compute, ds) = setup();
        let model = NativeModel::new(compute.dims().0, compute.dims().1);
        // θ = 0 → all probabilities 0.5 → ties → AUC 0.5 exactly
        let theta = vec![0.0f32; model.p()];
        let a = auc(&compute, &theta, &ds.test).unwrap();
        assert!((a - 0.5).abs() < 1e-9, "auc {a}");
    }
}
