//! Baseline trainers the paper's intro compares against:
//! the *fictitious fusion center* (centralized SGD on pooled data) and
//! star-network FedAvg (McMahan et al., 2017).
//!
//! Both are thin adapters over [`crate::engine`]: they run the SAME
//! [`RoundEngine`](crate::engine::RoundEngine) loop as the decentralized
//! drivers with the `FedAvgStrategy` / `CentralizedStrategy` communication
//! update plugged in, so EXP-A4's comm-cost/quality comparison is
//! apples-to-apples by construction — same samplers, same lr schedule, same
//! eval cadence, same metric shapes.

use crate::config::ExperimentConfig;
use crate::data::{FederatedDataset, Shard};
use crate::engine;
use crate::metrics::RunLog;
use anyhow::Result;

use super::compute::Compute;

/// Centralized SGD on the pooled cohort — the fusion center the paper argues
/// is infeasible for patient data.  Zero communication by construction; the
/// "comm round" axis advances every Q steps so curves align with FD runs.
pub fn centralized(
    cfg: &ExperimentConfig,
    compute: &dyn Compute,
    ds: &FederatedDataset,
) -> Result<RunLog> {
    let (log, _theta) = engine::train_centralized(cfg, compute, ds)?;
    Ok(log)
}

/// Star-network FedAvg: every round each client takes Q local steps from the
/// server parameters, the server averages.  Uses the star graph for comm
/// accounting (client↑ + server↓ per round).
pub fn fedavg(
    cfg: &ExperimentConfig,
    compute: &dyn Compute,
    ds: &FederatedDataset,
) -> Result<RunLog> {
    let (log, _theta) = engine::train_fedavg(cfg, compute, ds)?;
    Ok(log)
}

/// Test-set AUC for a trained parameter vector (trapezoidal ROC integral) —
/// used by examples to report held-out discrimination.
pub fn auc(compute: &dyn Compute, theta: &[f32], test: &Shard) -> Result<f64> {
    let probs = compute.predict(theta, &test.x)?;
    let mut pairs: Vec<(f32, f32)> = probs.iter().copied().zip(test.y.iter().copied()).collect();
    pairs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    // rank-sum (Mann–Whitney) AUC with tie handling by average rank
    let n_pos = pairs.iter().filter(|(_, y)| *y == 1.0).count();
    let n_neg = pairs.len() - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return Ok(0.5);
    }
    let mut rank_sum = 0.0f64;
    let mut i = 0usize;
    while i < pairs.len() {
        let mut j = i;
        while j < pairs.len() && pairs[j].0 == pairs[i].0 {
            j += 1;
        }
        let avg_rank = (i + j + 1) as f64 / 2.0; // 1-based average rank
        for k in i..j {
            if pairs[k].1 == 1.0 {
                rank_sum += avg_rank;
            }
        }
        i = j;
    }
    Ok((rank_sum - n_pos as f64 * (n_pos as f64 + 1.0) / 2.0) / (n_pos as f64 * n_neg as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::native::NativeModel;
    use crate::config::AlgoKind;
    use crate::coordinator::compute::NativeCompute;
    use crate::data::{generate, DataConfig};

    fn setup() -> (ExperimentConfig, NativeCompute, FederatedDataset) {
        let mut cfg = ExperimentConfig::default();
        cfg.n = 4;
        cfg.hidden = 8;
        cfg.m = 10;
        cfg.q = 5;
        cfg.total_steps = 100;
        cfg.eval_every = 2;
        cfg.records_per_hospital = 60;
        let ds = generate(&DataConfig {
            n_hospitals: 4,
            records_per_hospital: 60,
            records_jitter: 0,
            heterogeneity: 0.4,
            ..DataConfig::default()
        })
        .unwrap();
        let compute = NativeCompute::new(cfg.d, cfg.hidden, cfg.n, cfg.m);
        (cfg, compute, ds)
    }

    #[test]
    fn centralized_trains_with_zero_comm() {
        let (cfg, compute, ds) = setup();
        let log = centralized(&cfg, &compute, &ds).unwrap();
        assert!(log.rows.last().unwrap().loss < log.rows.first().unwrap().loss);
        assert_eq!(log.rows.last().unwrap().bytes, 0);
        assert_eq!(log.rows.last().unwrap().consensus, 0.0);
    }

    #[test]
    fn fedavg_trains_and_pays_star_bytes() {
        let (mut cfg, compute, ds) = setup();
        cfg.algo = AlgoKind::FedAvg;
        let log = fedavg(&cfg, &compute, &ds).unwrap();
        assert!(log.rows.last().unwrap().loss < log.rows.first().unwrap().loss);
        let rounds = log.rows.last().unwrap().comm_rounds;
        let p = compute.dims().2;
        assert_eq!(log.rows.last().unwrap().bytes, rounds * 2 * 4 * (p * 4) as u64);
        // consensus identically zero: all clients leave from server params
        assert_eq!(log.rows.last().unwrap().consensus, 0.0);
    }

    #[test]
    fn auc_on_separable_data_is_high() {
        let (cfg, compute, _) = setup();
        let _ = cfg;
        // fabricate a test shard scored perfectly by construction
        let d = compute.dims().0;
        let model = NativeModel::new(d, compute.dims().1);
        let mut rng = crate::rng::Pcg64::seed(0);
        let theta = model.init(&mut rng);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..50 {
            let row: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let z = model.logits(&theta, &row)[0];
            x.extend_from_slice(&row);
            y.push(if z > 0.0 { 1.0 } else { 0.0 });
            let _ = i;
        }
        let test = Shard { n: 50, d, x, y };
        let a = auc(&compute, &theta, &test).unwrap();
        assert!(a > 0.99, "auc {a}");
    }

    #[test]
    fn auc_of_random_scores_near_half() {
        let (_, compute, ds) = setup();
        let model = NativeModel::new(compute.dims().0, compute.dims().1);
        // θ = 0 → all probabilities 0.5 → ties → AUC 0.5 exactly
        let theta = vec![0.0f32; model.p()];
        let a = auc(&compute, &theta, &ds.test).unwrap();
        assert!((a - 0.5).abs() < 1e-9, "auc {a}");
    }
}
