//! The compute interface the training drivers run against.
//!
//! [`Compute`] abstracts the six artifact-level operations (DESIGN.md §4).
//! The production implementation is [`PjrtCompute`] — AOT artifacts through
//! the PJRT engine, python nowhere in sight.  [`NativeCompute`] adapts the
//! pure-rust twin (`algo::native`) for shape-free sweeps, property tests,
//! and as the numerical oracle the integration tests compare PJRT against.

use crate::algo::native::NativeModel;
use crate::data::Shard;
use crate::runtime::Engine;
use anyhow::{bail, Result};

/// Artifact-level compute operations over flat f32 buffers.
pub trait Compute {
    /// (d, hidden, p) of the model this backend computes.
    fn dims(&self) -> (usize, usize, usize);

    /// Number of scan steps the `local_steps` op performs per call
    /// (Q−1 for the artifact set; arbitrary for the native backend).
    fn local_steps_len(&self) -> Option<usize>;

    /// One stochastic gradient: → (loss, grad[p]).
    fn grad_step(&self, theta: &[f32], x: &[f32], y: &[f32]) -> Result<(f64, Vec<f32>)>;

    /// `lrs.len()` eq.-4 updates on pre-sampled batches
    /// (bx `[len,m,d]`, by `[len,m]`) → (θ′, per-step losses).
    fn local_steps(&self, theta: &[f32], bx: &[f32], by: &[f32], lrs: &[f32])
        -> Result<(Vec<f32>, Vec<f64>)>;

    /// Whole-network local phase: every node's `local_steps` in one call
    /// (bx `[n,len,m,d]`, by `[n,len,m]`, shared lrs).  Default: loop over
    /// nodes; backends override with a fused implementation (§Perf).
    fn local_steps_all(
        &self,
        big_theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lrs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f64>)> {
        let (_, _, p) = self.dims();
        let n = big_theta.len() / p;
        let (bxn, byn) = (bx.len() / n, by.len() / n);
        let mut theta_out = Vec::with_capacity(big_theta.len());
        let mut losses = Vec::new();
        for i in 0..n {
            let (t, l) = self.local_steps(
                &big_theta[i * p..(i + 1) * p],
                &bx[i * bxn..(i + 1) * bxn],
                &by[i * byn..(i + 1) * byn],
                lrs,
            )?;
            theta_out.extend_from_slice(&t);
            losses.extend_from_slice(&l);
        }
        Ok((theta_out, losses))
    }

    /// One node's gossip combine `Σ_j w_j θ_j` over stacked `[n,p]` params.
    fn combine(&self, wrow: &[f32], thetas: &[f32]) -> Result<Vec<f32>>;

    /// Whole-network eq. 2 round → (Θ′ `[n,p]`, losses `[n]`).
    fn dsgd_round(&self, w: &[f32], theta: &[f32], bx: &[f32], by: &[f32], lr: f32)
        -> Result<(Vec<f32>, Vec<f64>)>;

    /// Whole-network eq. 3 round → (Θ′, Y′, G′, losses).
    #[allow(clippy::too_many_arguments)]
    fn dsgt_round(
        &self,
        w: &[f32],
        theta: &[f32],
        y_tr: &[f32],
        g_old: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f64>)>;

    /// Full-shard metrics → (loss, accuracy, stationarity, consensus).
    fn eval_full(&self, theta: &[f32], shards: &[Shard]) -> Result<(f64, f64, f64, f64)>;

    /// P(AD | x) per row.
    fn predict(&self, theta: &[f32], x: &[f32]) -> Result<Vec<f32>>;
}

// ---------------------------------------------------------------- PJRT ----

/// Production backend: every op is an AOT artifact executed through PJRT.
pub struct PjrtCompute {
    engine: Engine,
}

impl PjrtCompute {
    pub fn new(engine: Engine) -> Self {
        PjrtCompute { engine }
    }

    pub fn load(dir: &std::path::Path) -> Result<Self> {
        Ok(PjrtCompute { engine: Engine::load(dir)? })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl Compute for PjrtCompute {
    fn dims(&self) -> (usize, usize, usize) {
        let s = self.engine.shapes();
        (s.d, s.hidden, s.p)
    }

    fn local_steps_len(&self) -> Option<usize> {
        self.engine
            .manifest()
            .spec("local_steps")
            .ok()
            .map(|s| s.inputs[3][0])
    }

    fn grad_step(&self, theta: &[f32], x: &[f32], y: &[f32]) -> Result<(f64, Vec<f32>)> {
        let mut out = self.engine.execute("grad_step", &[theta, x, y])?;
        let grad = out.pop().unwrap();
        let loss = out.pop().unwrap()[0] as f64;
        Ok((loss, grad))
    }

    fn local_steps(
        &self,
        theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lrs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f64>)> {
        if lrs.is_empty() {
            return Ok((theta.to_vec(), Vec::new()));
        }
        let want = self.local_steps_len().unwrap_or(0);
        if lrs.len() != want {
            bail!(
                "local_steps artifact is specialized to {want} steps, got {} \
                 (re-run `make artifacts Q=...`)",
                lrs.len()
            );
        }
        let mut out = self.engine.execute("local_steps", &[theta, bx, by, lrs])?;
        let losses = out.pop().unwrap().into_iter().map(|v| v as f64).collect();
        let theta_next = out.pop().unwrap();
        Ok((theta_next, losses))
    }

    // local_steps_all: the trait's per-node-loop default is used.  Measured on
    // this testbed the per-node `local_steps` scan (one grid step per tile)
    // beats the batched `local_steps_all` artifact ~2x for the local phase;
    // the batched artifact is still lowered and timed by bench_runtime so the
    // §Perf record keeps both numbers (see EXPERIMENTS.md).

    fn combine(&self, wrow: &[f32], thetas: &[f32]) -> Result<Vec<f32>> {
        let mut out = self.engine.execute("combine", &[wrow, thetas])?;
        Ok(out.pop().unwrap())
    }

    fn dsgd_round(
        &self,
        w: &[f32],
        theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f64>)> {
        let lr_buf = [lr];
        let mut out = self.engine.execute("dsgd_round", &[w, theta, bx, by, &lr_buf])?;
        let losses = out.pop().unwrap().into_iter().map(|v| v as f64).collect();
        let theta_next = out.pop().unwrap();
        Ok((theta_next, losses))
    }

    fn dsgt_round(
        &self,
        w: &[f32],
        theta: &[f32],
        y_tr: &[f32],
        g_old: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f64>)> {
        let lr_buf = [lr];
        let mut out = self
            .engine
            .execute("dsgt_round", &[w, theta, y_tr, g_old, bx, by, &lr_buf])?;
        let losses: Vec<f64> = out.pop().unwrap().into_iter().map(|v| v as f64).collect();
        let g_new = out.pop().unwrap();
        let y_next = out.pop().unwrap();
        let theta_next = out.pop().unwrap();
        Ok((theta_next, y_next, g_new, losses))
    }

    fn eval_full(&self, theta: &[f32], shards: &[Shard]) -> Result<(f64, f64, f64, f64)> {
        let s = self.engine.shapes();
        if shards.len() != s.n {
            bail!("eval_full wants {} shards, got {}", s.n, shards.len());
        }
        // the artifact is specialized to `shard` rows per node: cycle-pad
        let mut xs = Vec::with_capacity(s.n * s.shard * s.d);
        let mut ys = Vec::with_capacity(s.n * s.shard);
        for sh in shards {
            if sh.n == 0 {
                bail!("empty shard in eval_full");
            }
            for i in 0..s.shard {
                xs.extend_from_slice(sh.row(i % sh.n));
                ys.push(sh.y[i % sh.n]);
            }
        }
        let out = self.engine.execute("eval_full", &[theta, &xs, &ys])?;
        Ok((out[0][0] as f64, out[1][0] as f64, out[2][0] as f64, out[3][0] as f64))
    }

    fn predict(&self, theta: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let s = self.engine.shapes();
        let d = s.d;
        let rows = x.len() / d;
        // artifact is specialized to `shard` rows: chunk with cycle-padding
        let mut out = Vec::with_capacity(rows);
        let mut start = 0;
        while start < rows {
            let take = (rows - start).min(s.shard);
            let mut chunk = Vec::with_capacity(s.shard * d);
            for i in 0..s.shard {
                let src = start + (i % take);
                chunk.extend_from_slice(&x[src * d..(src + 1) * d]);
            }
            let res = self.engine.execute("predict", &[theta, &chunk])?;
            out.extend_from_slice(&res[0][..take]);
            start += take;
        }
        Ok(out)
    }
}

// -------------------------------------------------------------- native ----

/// Deterministic parallel map over node indices: node `i`'s result is
/// computed on whichever worker owns its chunk, then reassembled in index
/// order.  Because every node's work reads shared inputs and produces an
/// independent value, the output is bitwise-identical at every thread
/// count — parallelism never reorders a floating-point reduction.
fn par_map<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let chunk = n.div_ceil(threads);
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    std::thread::scope(|s| {
        for (ti, slot) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                for (k, o) in slot.iter_mut().enumerate() {
                    *o = Some(f(ti * chunk + k));
                }
            });
        }
    });
    out.into_iter().map(|o| o.expect("par_map: every slot filled")).collect()
}

/// Pure-rust backend (oracle / sweeps). `q_local` bounds nothing — any
/// number of local steps per call is accepted.
///
/// Whole-network ops (`local_steps_all`, `dsgd_round`, `dsgt_round`,
/// `eval_full`) fan nodes out over scoped threads: per-node work is
/// embarrassingly parallel over disjoint `[i*p..(i+1)*p]` slices, and all
/// cross-node reductions run serially in node order, so results are
/// bitwise-identical to the serial path (`threads = 1`).
#[derive(Clone, Copy, Debug)]
pub struct NativeCompute {
    pub model: NativeModel,
    pub n: usize,
    pub m: usize,
    /// Worker threads for whole-network ops: 0 = auto (one per core).
    pub threads: usize,
}

impl NativeCompute {
    pub fn new(d: usize, h: usize, n: usize, m: usize) -> Self {
        NativeCompute { model: NativeModel::new(d, h), n, m, threads: 0 }
    }

    /// Set the worker-thread count (builder style); 0 = auto, 1 = serial.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Effective pool size for a fan-out over `nodes` work items.
    fn pool(&self, nodes: usize) -> usize {
        let t = if self.threads == 0 {
            std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
        } else {
            self.threads
        };
        t.min(nodes).max(1)
    }
}

impl Compute for NativeCompute {
    fn dims(&self) -> (usize, usize, usize) {
        (self.model.d, self.model.h, self.model.p())
    }

    fn local_steps_len(&self) -> Option<usize> {
        None // any length accepted
    }

    fn grad_step(&self, theta: &[f32], x: &[f32], y: &[f32]) -> Result<(f64, Vec<f32>)> {
        Ok(self.model.loss_and_grad(theta, x, y))
    }

    fn local_steps(
        &self,
        theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lrs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f64>)> {
        let mut t = theta.to_vec();
        let losses = self.model.local_steps(&mut t, bx, by, lrs);
        Ok((t, losses))
    }

    fn local_steps_all(
        &self,
        big_theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lrs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f64>)> {
        let p = self.model.p();
        let nodes = big_theta.len() / p;
        if nodes == 0 || lrs.is_empty() {
            return Ok((big_theta.to_vec(), Vec::new()));
        }
        let (bxn, byn) = (bx.len() / nodes, by.len() / nodes);
        let per = par_map(self.pool(nodes), nodes, |i| {
            let mut t = big_theta[i * p..(i + 1) * p].to_vec();
            let losses = self.model.local_steps(
                &mut t,
                &bx[i * bxn..(i + 1) * bxn],
                &by[i * byn..(i + 1) * byn],
                lrs,
            );
            (t, losses)
        });
        let mut theta_out = Vec::with_capacity(nodes * p);
        let mut losses = Vec::with_capacity(nodes * lrs.len());
        for (t, l) in per {
            theta_out.extend_from_slice(&t);
            losses.extend_from_slice(&l);
        }
        Ok((theta_out, losses))
    }

    fn combine(&self, wrow: &[f32], thetas: &[f32]) -> Result<Vec<f32>> {
        Ok(self.model.combine(wrow, thetas))
    }

    fn dsgd_round(
        &self,
        w: &[f32],
        theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f64>)> {
        let (n, m, p, d) = (self.n, self.m, self.model.p(), self.model.d);
        let per = par_map(self.pool(n), n, |i| {
            self.model.dsgd_node(
                &w[i * n..(i + 1) * n],
                theta,
                &theta[i * p..(i + 1) * p],
                &bx[i * m * d..(i + 1) * m * d],
                &by[i * m..(i + 1) * m],
                lr,
            )
        });
        let mut out = Vec::with_capacity(n * p);
        let mut losses = Vec::with_capacity(n);
        for (t, loss) in per {
            out.extend_from_slice(&t);
            losses.push(loss);
        }
        Ok((out, losses))
    }

    fn dsgt_round(
        &self,
        w: &[f32],
        theta: &[f32],
        y_tr: &[f32],
        g_old: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f64>)> {
        let (n, m, p, d) = (self.n, self.m, self.model.p(), self.model.d);
        // node i depends only on row i of Y/G plus shared Θ/Y — the whole
        // eq.-3 round fans out per node with no cross-node ordering
        let per = par_map(self.pool(n), n, |i| {
            self.model.dsgt_node(
                &w[i * n..(i + 1) * n],
                theta,
                y_tr,
                &y_tr[i * p..(i + 1) * p],
                &g_old[i * p..(i + 1) * p],
                &bx[i * m * d..(i + 1) * m * d],
                &by[i * m..(i + 1) * m],
                lr,
            )
        });
        let mut theta_next = Vec::with_capacity(n * p);
        let mut y_out = Vec::with_capacity(n * p);
        let mut g_new = Vec::with_capacity(n * p);
        let mut losses = Vec::with_capacity(n);
        for (t, y, g, loss) in per {
            theta_next.extend_from_slice(&t);
            y_out.extend_from_slice(&y);
            g_new.extend_from_slice(&g);
            losses.push(loss);
        }
        Ok((theta_next, y_out, g_new, losses))
    }

    fn eval_full(&self, theta: &[f32], shards: &[Shard]) -> Result<(f64, f64, f64, f64)> {
        let p = self.model.p();
        let n = shards.len();
        if theta.len() != n * p {
            bail!("eval_full: theta len {} vs {} shards x p={p}", theta.len(), n);
        }
        // per-node partials in parallel; the reduction runs serially in node
        // order inside eval_reduce → bitwise-equal to the serial twin
        let per = par_map(self.pool(n), n, |i| {
            self.model.eval_node(&theta[i * p..(i + 1) * p], &shards[i])
        });
        Ok(self.model.eval_reduce(theta, &per))
    }

    fn predict(&self, theta: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        Ok(self.model.predict(theta, x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn native_compute_roundtrip() {
        let c = NativeCompute::new(6, 4, 3, 5);
        let (d, h, p) = c.dims();
        assert_eq!((d, h), (6, 4));
        assert_eq!(p, 33);
        let mut rng = Pcg64::seed(0);
        let theta: Vec<f32> = (0..p).map(|_| (rng.normal() * 0.2) as f32).collect();
        let x: Vec<f32> = (0..5 * 6).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..5).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        let (loss, grad) = c.grad_step(&theta, &x, &y).unwrap();
        assert!(loss.is_finite());
        assert_eq!(grad.len(), p);
        // empty local phase is identity
        let (t2, losses) = c.local_steps(&theta, &[], &[], &[]).unwrap();
        assert_eq!(t2, theta);
        assert!(losses.is_empty());
    }
}
