//! The compute interface the training drivers run against.
//!
//! [`Compute`] abstracts the six artifact-level operations (DESIGN.md §4).
//! The production implementation is [`PjrtCompute`] — AOT artifacts through
//! the PJRT engine, python nowhere in sight.  [`NativeCompute`] adapts the
//! pure-rust twin (`algo::native`) for shape-free sweeps, property tests,
//! and as the numerical oracle the integration tests compare PJRT against.

use crate::algo::{add_diff, axpy, RobustRule};
use crate::algo::native::{NativeModel, Workspace};
use crate::data::Shard;
use crate::mixing::SparseW;
use crate::runtime::Engine;
use anyhow::{bail, ensure, Result};

/// One communication round's mixing matrix in the forms the backends
/// consume: the degree-sparse CSR rows (what the native kernels gossip
/// over), plus an optional row-major dense `[n, n]` scatter (the AOT
/// artifacts' input).  When present, the two must describe the same matrix.
/// Drivers materialize the dense form only for backends that report
/// [`Compute::wants_dense_w`] — at 10⁵ nodes an n×n buffer is 40 GB, so the
/// sparse-native path never builds it.
pub struct MixView<'a> {
    /// Row-major dense `[n, n]` f32 mixing matrix, if the backend asked for
    /// it ([`Compute::wants_dense_w`]); `None` on the sparse-native path.
    pub dense: Option<&'a [f32]>,
    /// Degree-sparse CSR rows of the mixing matrix (always present).
    pub sparse: &'a SparseW,
}

/// Artifact-level compute operations over flat f32 buffers.
pub trait Compute {
    /// (d, hidden, p) of the model this backend computes.
    fn dims(&self) -> (usize, usize, usize);

    /// Number of scan steps the `local_steps` op performs per call
    /// (Q−1 for the artifact set; arbitrary for the native backend).
    fn local_steps_len(&self) -> Option<usize>;

    /// Does this backend need the dense `[n, n]` mixing matrix in its
    /// [`MixView`]?  Defaults to `true` (the AOT artifacts take dense W);
    /// sparse-native backends override to `false` so drivers never scatter —
    /// or even allocate — an n×n buffer, which is what lets the network axis
    /// scale to 10⁵–10⁶ nodes.
    fn wants_dense_w(&self) -> bool {
        true
    }

    /// One stochastic gradient: → (loss, grad[p]).
    fn grad_step(&self, theta: &[f32], x: &[f32], y: &[f32]) -> Result<(f64, Vec<f32>)>;

    /// `lrs.len()` eq.-4 updates on pre-sampled batches
    /// (bx `[len,m,d]`, by `[len,m]`) → (θ′, per-step losses).
    fn local_steps(&self, theta: &[f32], bx: &[f32], by: &[f32], lrs: &[f32])
        -> Result<(Vec<f32>, Vec<f64>)>;

    /// Whole-network local phase: every node's `local_steps` in one call
    /// (bx `[n,len,m,d]`, by `[n,len,m]`, shared lrs).  Default: loop over
    /// nodes; backends override with a fused implementation (§Perf).
    fn local_steps_all(
        &self,
        big_theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lrs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f64>)> {
        let (_, _, p) = self.dims();
        let n = big_theta.len() / p;
        if n == 0 {
            // guard the n-divisions below: silently proceeding would panic
            // on divide-by-zero far from the actual mistake
            bail!(
                "local_steps_all on an empty Θ stack (theta len {} < p = {p}); \
                 every trainer owns at least one stack row",
                big_theta.len()
            );
        }
        let (bxn, byn) = (bx.len() / n, by.len() / n);
        let mut theta_out = Vec::with_capacity(big_theta.len());
        let mut losses = Vec::new();
        for i in 0..n {
            let (t, l) = self.local_steps(
                &big_theta[i * p..(i + 1) * p],
                &bx[i * bxn..(i + 1) * bxn],
                &by[i * byn..(i + 1) * byn],
                lrs,
            )?;
            theta_out.extend_from_slice(&t);
            losses.extend_from_slice(&l);
        }
        Ok((theta_out, losses))
    }

    /// [`Compute::local_steps_all`] into caller-owned slabs: θ′ →
    /// `theta_out[n·p]`, per-step losses → `losses[n·lrs.len()]`.  Default:
    /// delegate to the allocating op and copy; zero-allocation backends
    /// override (§Perf).
    fn local_steps_all_into(
        &self,
        big_theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lrs: &[f32],
        theta_out: &mut [f32],
        losses: &mut [f64],
    ) -> Result<()> {
        let (t, l) = self.local_steps_all(big_theta, bx, by, lrs)?;
        theta_out.copy_from_slice(&t);
        losses.copy_from_slice(&l);
        Ok(())
    }

    /// Whole-network local phase under a **heterogeneous compute plan**
    /// (`engine::stragglers`): node `i` runs only its first
    /// `min(taus[i] − 1, lrs.len())` eq.-4 steps, consuming the *prefix* of
    /// its pre-sampled batches and of the shared lr buffer (batches beyond a
    /// straggler's count are drawn but unused, keeping sampler streams
    /// plan-independent — §7).  Rows with zero local steps copy through
    /// unchanged; loss-slab entries past a node's step count are zeroed.
    /// Default: per-node `local_steps` on truncated slices — exactly the
    /// call sequence the actor driver issues, so any backend stays
    /// bitwise-aligned with the actor path.  The native backend overrides
    /// with the threaded zero-copy fan-out.
    #[allow(clippy::too_many_arguments)]
    fn local_steps_hetero_into(
        &self,
        big_theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lrs: &[f32],
        taus: &[usize],
        theta_out: &mut [f32],
        losses: &mut [f64],
    ) -> Result<()> {
        let (_, _, p) = self.dims();
        let n = big_theta.len() / p;
        if n == 0 {
            bail!(
                "local_steps_hetero on an empty Θ stack (theta len {} < p = {p})",
                big_theta.len()
            );
        }
        ensure!(taus.len() == n, "τ schedule covers {} rows, stack has {n}", taus.len());
        ensure!(theta_out.len() == big_theta.len(), "theta_out size mismatch");
        ensure!(losses.len() == n * lrs.len(), "losses slab size mismatch");
        let (bxn, byn) = (bx.len() / n, by.len() / n);
        let local = lrs.len();
        let (bxs, bys) = (bxn / local.max(1), byn / local.max(1));
        for i in 0..n {
            let li = taus[i].saturating_sub(1).min(local);
            let lrow = &mut losses[i * local..(i + 1) * local];
            if li == 0 {
                theta_out[i * p..(i + 1) * p].copy_from_slice(&big_theta[i * p..(i + 1) * p]);
                for l in lrow.iter_mut() {
                    *l = 0.0;
                }
                continue;
            }
            let (t, l) = self.local_steps(
                &big_theta[i * p..(i + 1) * p],
                &bx[i * bxn..i * bxn + li * bxs],
                &by[i * byn..i * byn + li * bys],
                &lrs[..li],
            )?;
            theta_out[i * p..(i + 1) * p].copy_from_slice(&t);
            lrow[..li].copy_from_slice(&l);
            for l in lrow[li..].iter_mut() {
                *l = 0.0;
            }
        }
        Ok(())
    }

    /// One node's gossip combine `Σ_j w_j θ_j` over stacked `[n,p]` params.
    fn combine(&self, wrow: &[f32], thetas: &[f32]) -> Result<Vec<f32>>;

    /// One node's gossip combine over its degree-sparse W row: `(idx, val)`
    /// pairs, ascending, nonzeros only — bitwise-equal to [`Compute::combine`]
    /// on the dense row with those nonzeros.  `node` names the row's owner
    /// (always a participant): the robust rules need it for the k < 3
    /// keep-self guard; the mean path ignores it.  Default: scatter the row
    /// dense and call `combine` (artifact backends take dense W); the
    /// native backend overrides with the O(deg·p) kernel.
    fn combine_sparse(&self, _node: u32, idx: &[u32], val: &[f32], thetas: &[f32]) -> Result<Vec<f32>> {
        let (_, _, p) = self.dims();
        ensure!(p > 0 && thetas.len() % p == 0, "thetas not a multiple of p");
        let n = thetas.len() / p;
        let mut wrow = vec![0.0f32; n];
        for (&j, &v) in idx.iter().zip(val) {
            wrow[j as usize] = v;
        }
        self.combine(&wrow, thetas)
    }

    /// Whole-network eq. 2 round → (Θ′ `[n,p]`, losses `[n]`).
    fn dsgd_round(&self, w: &[f32], theta: &[f32], bx: &[f32], by: &[f32], lr: f32)
        -> Result<(Vec<f32>, Vec<f64>)>;

    /// [`Compute::dsgd_round`] into caller-owned slabs (θ′ → `theta_out`,
    /// per-node losses → `losses[n]`), taking the round's W in both dense
    /// and sparse form.  Default: delegate to the dense allocating op and
    /// copy; the native backend overrides with the degree-sparse
    /// zero-allocation path.
    #[allow(clippy::too_many_arguments)]
    fn dsgd_round_into(
        &self,
        w: &MixView,
        theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
        theta_out: &mut [f32],
        losses: &mut [f64],
    ) -> Result<()> {
        let Some(dense) = w.dense else {
            bail!(
                "this backend's dsgd_round consumes dense W (wants_dense_w), \
                 but the driver supplied a sparse-only MixView"
            );
        };
        let (t, l) = self.dsgd_round(dense, theta, bx, by, lr)?;
        theta_out.copy_from_slice(&t);
        losses.copy_from_slice(&l);
        Ok(())
    }

    /// Whole-network eq. 3 round → (Θ′, Y′, G′, losses).
    #[allow(clippy::too_many_arguments)]
    fn dsgt_round(
        &self,
        w: &[f32],
        theta: &[f32],
        y_tr: &[f32],
        g_old: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f64>)>;

    /// [`Compute::dsgt_round`] into caller-owned slabs (Θ′/Y′/G′ →
    /// `[n·p]` each, per-node losses → `losses[n]`).  Default: delegate to
    /// the dense allocating op and copy; overridden by the native backend.
    #[allow(clippy::too_many_arguments)]
    fn dsgt_round_into(
        &self,
        w: &MixView,
        theta: &[f32],
        y_tr: &[f32],
        g_old: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
        theta_out: &mut [f32],
        y_out: &mut [f32],
        g_out: &mut [f32],
        losses: &mut [f64],
    ) -> Result<()> {
        let Some(dense) = w.dense else {
            bail!(
                "this backend's dsgt_round consumes dense W (wants_dense_w), \
                 but the driver supplied a sparse-only MixView"
            );
        };
        let (t, y, g, l) = self.dsgt_round(dense, theta, y_tr, g_old, bx, by, lr)?;
        theta_out.copy_from_slice(&t);
        y_out.copy_from_slice(&y);
        g_out.copy_from_slice(&g);
        losses.copy_from_slice(&l);
        Ok(())
    }

    /// Whole-network eq.-2 round under **compressed gossip** — the
    /// difference form of DESIGN.md §10: the mixing term reads the decoded
    /// stack `xhat` (what actually crossed the wire), each node adds back
    /// its own full-precision correction `θ_i − x̂_i`, and the gradient is
    /// taken at the true `θ_i`:
    /// `θ′_i = (W X̂)_i + (θ_i − x̂_i) − lr ∇g_i(θ_i)`.
    /// The correction makes compression exactly mean-preserving under a
    /// doubly stochastic W — lossy messages perturb only the consensus
    /// direction, never the average iterate.
    ///
    /// Default: per-node `combine_sparse` + `add_diff` + `grad_step` —
    /// exactly the ops the actor driver's node loop issues, so any backend
    /// stays bitwise-aligned with the actor path.  The native backend
    /// overrides with the threaded zero-copy fan-out.
    #[allow(clippy::too_many_arguments)]
    fn dsgd_round_compressed_into(
        &self,
        w: &MixView,
        xhat: &[f32],
        theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
        theta_out: &mut [f32],
        losses: &mut [f64],
    ) -> Result<()> {
        let (_, _, p) = self.dims();
        let n = theta.len() / p;
        ensure!(n > 0 && theta.len() == n * p, "theta stack not a multiple of p");
        ensure!(xhat.len() == n * p, "decoded stack size mismatch");
        ensure!(theta_out.len() == n * p && losses.len() == n, "output slab size mismatch");
        let (m, md) = (by.len() / n, bx.len() / n);
        for i in 0..n {
            let (idx, val) = w.sparse.row(i);
            let mixed = self.combine_sparse(i as u32, idx, val, xhat)?;
            let (loss, grad) = self.grad_step(
                &theta[i * p..(i + 1) * p],
                &bx[i * md..(i + 1) * md],
                &by[i * m..(i + 1) * m],
            )?;
            let out = &mut theta_out[i * p..(i + 1) * p];
            out.copy_from_slice(&mixed);
            add_diff(out, &theta[i * p..(i + 1) * p], &xhat[i * p..(i + 1) * p]);
            axpy(out, -lr, &grad);
            losses[i] = loss;
        }
        Ok(())
    }

    /// Whole-network eq.-3 round under **compressed gossip** (difference
    /// form): both mixes read decoded stacks with each node's own
    /// full-precision corrections added back:
    /// `θ′_i = (W X̂)_i + (θ_i − x̂_i) − lr ϑ_i`,
    /// `ϑ′_i = (W Ŷ)_i + (ϑ_i − ŷ_i) + ∇g(θ′_i) − ∇g(θ_i)`.
    /// Default mirrors the actor node ops; the native backend overrides
    /// with the threaded fan-out.
    #[allow(clippy::too_many_arguments)]
    fn dsgt_round_compressed_into(
        &self,
        w: &MixView,
        xhat: &[f32],
        yhat: &[f32],
        theta: &[f32],
        y_tr: &[f32],
        g_old: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
        theta_out: &mut [f32],
        y_out: &mut [f32],
        g_out: &mut [f32],
        losses: &mut [f64],
    ) -> Result<()> {
        let (_, _, p) = self.dims();
        let n = theta.len() / p;
        ensure!(n > 0 && theta.len() == n * p, "theta stack not a multiple of p");
        ensure!(xhat.len() == n * p && yhat.len() == n * p, "decoded stack size mismatch");
        ensure!(
            theta_out.len() == n * p && y_out.len() == n * p && g_out.len() == n * p
                && losses.len() == n,
            "output slab size mismatch"
        );
        let (m, md) = (by.len() / n, bx.len() / n);
        for i in 0..n {
            let row = i * p..(i + 1) * p;
            let (idx, val) = w.sparse.row(i);
            let mut t_next = self.combine_sparse(i as u32, idx, val, xhat)?;
            add_diff(&mut t_next, &theta[row.clone()], &xhat[row.clone()]);
            axpy(&mut t_next, -lr, &y_tr[row.clone()]);
            let (loss, g_new) =
                self.grad_step(&t_next, &bx[i * md..(i + 1) * md], &by[i * m..(i + 1) * m])?;
            let mut y_next = self.combine_sparse(i as u32, idx, val, yhat)?;
            add_diff(&mut y_next, &y_tr[row.clone()], &yhat[row.clone()]);
            axpy(&mut y_next, 1.0, &g_new);
            axpy(&mut y_next, -1.0, &g_old[row.clone()]);
            theta_out[row.clone()].copy_from_slice(&t_next);
            y_out[row.clone()].copy_from_slice(&y_next);
            g_out[row].copy_from_slice(&g_new);
            losses[i] = loss;
        }
        Ok(())
    }

    /// Full-shard metrics → (loss, accuracy, stationarity, consensus).
    fn eval_full(&self, theta: &[f32], shards: &[Shard]) -> Result<(f64, f64, f64, f64)>;

    /// P(AD | x) per row.
    fn predict(&self, theta: &[f32], x: &[f32]) -> Result<Vec<f32>>;
}

// ---------------------------------------------------------------- PJRT ----

/// Production backend: every op is an AOT artifact executed through PJRT.
pub struct PjrtCompute {
    engine: Engine,
}

impl PjrtCompute {
    /// Wrap an already-loaded PJRT engine.
    pub fn new(engine: Engine) -> Self {
        PjrtCompute { engine }
    }

    /// Load the AOT artifact set from `dir` and build the engine.
    pub fn load(dir: &std::path::Path) -> Result<Self> {
        Ok(PjrtCompute { engine: Engine::load(dir)? })
    }

    /// The underlying PJRT engine (manifest, shapes, raw execute).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

impl Compute for PjrtCompute {
    fn dims(&self) -> (usize, usize, usize) {
        let s = self.engine.shapes();
        (s.d, s.hidden, s.p)
    }

    fn local_steps_len(&self) -> Option<usize> {
        self.engine
            .manifest()
            .spec("local_steps")
            .ok()
            .map(|s| s.inputs[3][0])
    }

    fn grad_step(&self, theta: &[f32], x: &[f32], y: &[f32]) -> Result<(f64, Vec<f32>)> {
        let mut out = self.engine.execute("grad_step", &[theta, x, y])?;
        let grad = out.pop().unwrap();
        let loss = out.pop().unwrap()[0] as f64;
        Ok((loss, grad))
    }

    fn local_steps(
        &self,
        theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lrs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f64>)> {
        if lrs.is_empty() {
            return Ok((theta.to_vec(), Vec::new()));
        }
        let want = self.local_steps_len().unwrap_or(0);
        if lrs.len() != want {
            bail!(
                "local_steps artifact is specialized to {want} steps, got {} \
                 (re-run `make artifacts Q=...`)",
                lrs.len()
            );
        }
        let mut out = self.engine.execute("local_steps", &[theta, bx, by, lrs])?;
        let losses = out.pop().unwrap().into_iter().map(|v| v as f64).collect();
        let theta_next = out.pop().unwrap();
        Ok((theta_next, losses))
    }

    // local_steps_all: the trait's per-node-loop default is used.  Measured on
    // this testbed the per-node `local_steps` scan (one grid step per tile)
    // beats the batched `local_steps_all` artifact ~2x for the local phase;
    // the batched artifact is still lowered and timed by bench_runtime so the
    // §Perf record keeps both numbers (see EXPERIMENTS.md).

    fn combine(&self, wrow: &[f32], thetas: &[f32]) -> Result<Vec<f32>> {
        let mut out = self.engine.execute("combine", &[wrow, thetas])?;
        Ok(out.pop().unwrap())
    }

    fn dsgd_round(
        &self,
        w: &[f32],
        theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f64>)> {
        let lr_buf = [lr];
        let mut out = self.engine.execute("dsgd_round", &[w, theta, bx, by, &lr_buf])?;
        let losses = out.pop().unwrap().into_iter().map(|v| v as f64).collect();
        let theta_next = out.pop().unwrap();
        Ok((theta_next, losses))
    }

    fn dsgt_round(
        &self,
        w: &[f32],
        theta: &[f32],
        y_tr: &[f32],
        g_old: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f64>)> {
        let lr_buf = [lr];
        let mut out = self
            .engine
            .execute("dsgt_round", &[w, theta, y_tr, g_old, bx, by, &lr_buf])?;
        let losses: Vec<f64> = out.pop().unwrap().into_iter().map(|v| v as f64).collect();
        let g_new = out.pop().unwrap();
        let y_next = out.pop().unwrap();
        let theta_next = out.pop().unwrap();
        Ok((theta_next, y_next, g_new, losses))
    }

    /// Full-shard metrics through the **masked** `eval_full` artifact —
    /// exact on uneven shards.
    ///
    /// The artifact is specialized to `s.shard` rows per node, so a shard
    /// with `sh.n < s.shard` rows is cycle-padded (row `i % sh.n`) — but the
    /// padded rows are shipped with a 0.0 entry in the per-row mask input,
    /// so the artifact's reduction ignores them entirely: per-node means run
    /// over exactly the real rows, and the global loss/accuracy are
    /// record-weighted over the true record counts, matching
    /// `NativeCompute::eval_full` (the reference oracle) on uneven shards.
    /// (The pre-mask artifact reported the mean over the *padded* rows,
    /// over-weighting the first `s.shard % sh.n` rows; the
    /// `cycle_padding_bias_*` test below keeps that bias arithmetic as
    /// documentation of what the mask eliminates.)  Shards *larger* than the
    /// artifact's capacity cannot be masked into shape and are rejected
    /// loudly rather than silently truncated.
    fn eval_full(&self, theta: &[f32], shards: &[Shard]) -> Result<(f64, f64, f64, f64)> {
        let s = self.engine.shapes();
        if shards.len() != s.n {
            bail!("eval_full wants {} shards, got {}", s.n, shards.len());
        }
        let spec = self.engine.manifest().spec("eval_full")?;
        if spec.inputs.len() < 4 {
            bail!(
                "this artifact set's eval_full predates masked evaluation ({} inputs): \
                 its cycle-padded reduction over-weights the first shard%n rows of an \
                 uneven shard; re-run `make artifacts` to regenerate the masked artifact",
                spec.inputs.len()
            );
        }
        // cycle-pad to the specialized row count; the mask zeroes the pad
        let mut xs = Vec::with_capacity(s.n * s.shard * s.d);
        let mut ys = Vec::with_capacity(s.n * s.shard);
        let mut mask = Vec::with_capacity(s.n * s.shard);
        for sh in shards {
            if sh.n == 0 {
                bail!("empty shard in eval_full");
            }
            if sh.n > s.shard {
                bail!(
                    "shard has {} records but the eval_full artifact is specialized to \
                     {} rows; evaluating a truncation would bias the metrics — re-run \
                     `make artifacts` with shard >= {}",
                    sh.n,
                    s.shard,
                    sh.n
                );
            }
            for i in 0..s.shard {
                xs.extend_from_slice(sh.row(i % sh.n));
                ys.push(sh.y[i % sh.n]);
                mask.push(if i < sh.n { 1.0f32 } else { 0.0 });
            }
        }
        let out = self.engine.execute("eval_full", &[theta, &xs, &ys, &mask])?;
        Ok((out[0][0] as f64, out[1][0] as f64, out[2][0] as f64, out[3][0] as f64))
    }

    fn predict(&self, theta: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        let s = self.engine.shapes();
        let d = s.d;
        let rows = x.len() / d;
        // artifact is specialized to `shard` rows: chunk with cycle-padding
        let mut out = Vec::with_capacity(rows);
        let mut start = 0;
        while start < rows {
            let take = (rows - start).min(s.shard);
            let mut chunk = Vec::with_capacity(s.shard * d);
            for i in 0..s.shard {
                let src = start + (i % take);
                chunk.extend_from_slice(&x[src * d..(src + 1) * d]);
            }
            let res = self.engine.execute("predict", &[theta, &chunk])?;
            out.extend_from_slice(&res[0][..take]);
            start += take;
        }
        Ok(out)
    }
}

// -------------------------------------------------------------- native ----

/// Deterministic parallel fan-out over per-node tasks.  Each task carries
/// its own disjoint `&mut` output views (rows of the caller's slabs), so
/// workers write results **in place** — no `Vec<Option<T>>`
/// collect-then-reassemble, no cross-thread reduction, and on the serial
/// path (`threads <= 1`) no allocation at all: the task iterator is
/// consumed directly.  Tasks are assigned to workers in contiguous index
/// chunks; results are bitwise-independent of thread count because every
/// task writes only through its own views.
fn par_each<T, I, F>(threads: usize, tasks: I, f: F)
where
    T: Send,
    I: ExactSizeIterator<Item = T>,
    F: Fn(usize, T) + Sync,
{
    let n = tasks.len();
    if threads <= 1 || n <= 1 {
        for (i, t) in tasks.enumerate() {
            f(i, t);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    let mut it = tasks;
    let mut batches: Vec<Vec<T>> = Vec::with_capacity(threads);
    loop {
        let batch: Vec<T> = it.by_ref().take(chunk).collect();
        if batch.is_empty() {
            break;
        }
        batches.push(batch);
    }
    std::thread::scope(|s| {
        let f = &f;
        for (bi, batch) in batches.into_iter().enumerate() {
            let base = bi * chunk;
            s.spawn(move || {
                for (k, t) in batch.into_iter().enumerate() {
                    f(base + k, t);
                }
            });
        }
    });
}

thread_local! {
    /// Per-thread kernel workspace: allocated lazily on a worker's first
    /// kernel call, then reused for every later call on that thread.  The
    /// serial path runs on the caller's (long-lived) thread, so steady-state
    /// rounds touch no allocator at all — the contract the
    /// `alloc_free` integration test pins.  Threaded fan-out workers are
    /// round-scoped, so they pay one O(p) workspace each per round (still
    /// far below the former n·O(p) fresh-`Vec` traffic).
    static KERNEL_WS: std::cell::RefCell<Workspace> = std::cell::RefCell::new(Workspace::new());
}

/// Run `f` with the calling thread's kernel workspace.
fn with_ws<R>(f: impl FnOnce(&mut Workspace) -> R) -> R {
    KERNEL_WS.with(|w| f(&mut w.borrow_mut()))
}

/// Pure-rust backend (oracle / sweeps). `q_local` bounds nothing — any
/// number of local steps per call is accepted.
///
/// Whole-network ops (`local_steps_all`, `dsgd_round`, `dsgt_round`,
/// `eval_full`) fan nodes out over scoped threads: per-node work is
/// embarrassingly parallel over disjoint `[i*p..(i+1)*p]` slices, and all
/// cross-node reductions run serially in node order, so results are
/// bitwise-identical to the serial path (`threads = 1`).
#[derive(Clone, Copy, Debug)]
pub struct NativeCompute {
    /// Model dimensions (the pure-rust twin of the artifact shapes).
    pub model: NativeModel,
    /// Hospital count the whole-network ops fan over.
    pub n: usize,
    /// Minibatch size per node per step.
    pub m: usize,
    /// Worker threads for whole-network ops: 0 = auto (one per core).
    pub threads: usize,
    /// How gossip rows aggregate their neighborhoods (DESIGN.md §14).
    /// [`RobustRule::Mean`] — the default — routes every combine through
    /// the pinned legacy kernels bit for bit; the robust rules screen
    /// Byzantine payloads at the cost of mean preservation.
    pub rule: RobustRule,
}

impl NativeCompute {
    /// Backend for a `d`-feature, `h`-hidden model over `n` nodes, batch `m`.
    pub fn new(d: usize, h: usize, n: usize, m: usize) -> Self {
        NativeCompute { model: NativeModel::new(d, h), n, m, threads: 0, rule: RobustRule::Mean }
    }

    /// Set the worker-thread count (builder style); 0 = auto, 1 = serial.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Set the gossip combine rule (builder style); every round kernel and
    /// `combine_sparse` dispatches through it, so the fused, actor, and
    /// async drivers all aggregate identically.
    pub fn with_robust_rule(mut self, rule: RobustRule) -> Self {
        self.rule = rule;
        self
    }

    /// Effective pool size for a fan-out over `nodes` work items.
    fn pool(&self, nodes: usize) -> usize {
        let t = if self.threads == 0 {
            std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
        } else {
            self.threads
        };
        t.min(nodes).max(1)
    }
}

impl Compute for NativeCompute {
    fn dims(&self) -> (usize, usize, usize) {
        (self.model.d, self.model.h, self.model.p())
    }

    fn local_steps_len(&self) -> Option<usize> {
        None // any length accepted
    }

    fn wants_dense_w(&self) -> bool {
        false // every native kernel gossips over the CSR rows
    }

    fn grad_step(&self, theta: &[f32], x: &[f32], y: &[f32]) -> Result<(f64, Vec<f32>)> {
        Ok(self.model.loss_and_grad(theta, x, y))
    }

    fn local_steps(
        &self,
        theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lrs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f64>)> {
        let mut t = theta.to_vec();
        let losses = self.model.local_steps(&mut t, bx, by, lrs);
        Ok((t, losses))
    }

    fn local_steps_all(
        &self,
        big_theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lrs: &[f32],
    ) -> Result<(Vec<f32>, Vec<f64>)> {
        let p = self.model.p();
        let nodes = big_theta.len() / p;
        let mut theta_out = vec![0.0f32; big_theta.len()];
        let mut losses = vec![0.0f64; nodes * lrs.len()];
        self.local_steps_all_into(big_theta, bx, by, lrs, &mut theta_out, &mut losses)?;
        Ok((theta_out, losses))
    }

    fn local_steps_all_into(
        &self,
        big_theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lrs: &[f32],
        theta_out: &mut [f32],
        losses: &mut [f64],
    ) -> Result<()> {
        let p = self.model.p();
        let nodes = big_theta.len() / p;
        if nodes == 0 {
            bail!(
                "local_steps_all on an empty Θ stack (theta len {} < p = {p})",
                big_theta.len()
            );
        }
        ensure!(theta_out.len() == big_theta.len(), "theta_out size mismatch");
        ensure!(losses.len() == nodes * lrs.len(), "losses slab size mismatch");
        theta_out.copy_from_slice(big_theta);
        if lrs.is_empty() {
            return Ok(());
        }
        let (bxn, byn) = (bx.len() / nodes, by.len() / nodes);
        let model = &self.model;
        par_each(
            self.pool(nodes),
            theta_out.chunks_mut(p).zip(losses.chunks_mut(lrs.len())),
            |i, (t, l)| {
                with_ws(|ws| {
                    model.local_steps_into(
                        t,
                        &bx[i * bxn..(i + 1) * bxn],
                        &by[i * byn..(i + 1) * byn],
                        lrs,
                        l,
                        ws,
                    )
                });
            },
        );
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn local_steps_hetero_into(
        &self,
        big_theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lrs: &[f32],
        taus: &[usize],
        theta_out: &mut [f32],
        losses: &mut [f64],
    ) -> Result<()> {
        let p = self.model.p();
        let nodes = big_theta.len() / p;
        if nodes == 0 {
            bail!(
                "local_steps_hetero on an empty Θ stack (theta len {} < p = {p})",
                big_theta.len()
            );
        }
        ensure!(taus.len() == nodes, "τ schedule covers {} rows, stack has {nodes}", taus.len());
        ensure!(theta_out.len() == big_theta.len(), "theta_out size mismatch");
        ensure!(losses.len() == nodes * lrs.len(), "losses slab size mismatch");
        theta_out.copy_from_slice(big_theta);
        let local = lrs.len();
        if local == 0 {
            return Ok(());
        }
        let (bxn, byn) = (bx.len() / nodes, by.len() / nodes);
        let (bxs, bys) = (bxn / local, byn / local);
        let model = &self.model;
        // per-node prefix truncation of the same kernel the uniform fan-out
        // runs — a node's first li steps are bitwise what the actor driver's
        // truncated `local_steps` call computes
        par_each(
            self.pool(nodes),
            theta_out.chunks_mut(p).zip(losses.chunks_mut(local)),
            |i, (t, l)| {
                let li = taus[i].saturating_sub(1).min(local);
                for tail in l[li..].iter_mut() {
                    *tail = 0.0;
                }
                if li == 0 {
                    return;
                }
                with_ws(|ws| {
                    model.local_steps_into(
                        t,
                        &bx[i * bxn..i * bxn + li * bxs],
                        &by[i * byn..i * byn + li * bys],
                        &lrs[..li],
                        &mut l[..li],
                        ws,
                    )
                });
            },
        );
        Ok(())
    }

    fn combine(&self, wrow: &[f32], thetas: &[f32]) -> Result<Vec<f32>> {
        Ok(self.model.combine(wrow, thetas))
    }

    fn combine_sparse(&self, node: u32, idx: &[u32], val: &[f32], thetas: &[f32]) -> Result<Vec<f32>> {
        let mut out = vec![0.0f32; self.model.p()];
        with_ws(|ws| self.model.combine_rule_into(self.rule, node, idx, val, thetas, &mut out, ws));
        Ok(out)
    }

    fn dsgd_round(
        &self,
        w: &[f32],
        theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f64>)> {
        let (n, p) = (self.n, self.model.p());
        let sparse = SparseW::from_dense(n, w);
        let mut out = vec![0.0f32; n * p];
        let mut losses = vec![0.0f64; n];
        self.dsgd_round_into(
            &MixView { dense: Some(w), sparse: &sparse },
            theta,
            bx,
            by,
            lr,
            &mut out,
            &mut losses,
        )?;
        Ok((out, losses))
    }

    fn dsgd_round_into(
        &self,
        w: &MixView,
        theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
        theta_out: &mut [f32],
        losses: &mut [f64],
    ) -> Result<()> {
        let (n, m, p, d) = (self.n, self.m, self.model.p(), self.model.d);
        ensure!(w.sparse.n() == n, "sparse W is {}x, compute wants n={n}", w.sparse.n());
        ensure!(theta_out.len() == n * p && losses.len() == n, "output slab size mismatch");
        let model = &self.model;
        let rule = self.rule;
        let sparse = w.sparse;
        par_each(
            self.pool(n),
            theta_out.chunks_mut(p).zip(losses.iter_mut()),
            |i, (out, loss)| {
                let (idx, val) = sparse.row(i);
                *loss = with_ws(|ws| {
                    model.dsgd_node_rule_into(
                        rule,
                        i as u32,
                        idx,
                        val,
                        theta,
                        &theta[i * p..(i + 1) * p],
                        &bx[i * m * d..(i + 1) * m * d],
                        &by[i * m..(i + 1) * m],
                        lr,
                        out,
                        ws,
                    )
                });
            },
        );
        Ok(())
    }

    fn dsgt_round(
        &self,
        w: &[f32],
        theta: &[f32],
        y_tr: &[f32],
        g_old: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f64>)> {
        let (n, p) = (self.n, self.model.p());
        let sparse = SparseW::from_dense(n, w);
        let mut theta_next = vec![0.0f32; n * p];
        let mut y_out = vec![0.0f32; n * p];
        let mut g_new = vec![0.0f32; n * p];
        let mut losses = vec![0.0f64; n];
        self.dsgt_round_into(
            &MixView { dense: Some(w), sparse: &sparse },
            theta,
            y_tr,
            g_old,
            bx,
            by,
            lr,
            &mut theta_next,
            &mut y_out,
            &mut g_new,
            &mut losses,
        )?;
        Ok((theta_next, y_out, g_new, losses))
    }

    fn dsgt_round_into(
        &self,
        w: &MixView,
        theta: &[f32],
        y_tr: &[f32],
        g_old: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
        theta_out: &mut [f32],
        y_out: &mut [f32],
        g_out: &mut [f32],
        losses: &mut [f64],
    ) -> Result<()> {
        let (n, m, p, d) = (self.n, self.m, self.model.p(), self.model.d);
        ensure!(w.sparse.n() == n, "sparse W is {}x, compute wants n={n}", w.sparse.n());
        ensure!(
            theta_out.len() == n * p && y_out.len() == n * p && g_out.len() == n * p
                && losses.len() == n,
            "output slab size mismatch"
        );
        let model = &self.model;
        let rule = self.rule;
        let sparse = w.sparse;
        // node i depends only on row i of Y/G plus shared Θ/Y — the whole
        // eq.-3 round fans out per node, each writing its own slab rows
        par_each(
            self.pool(n),
            theta_out
                .chunks_mut(p)
                .zip(y_out.chunks_mut(p))
                .zip(g_out.chunks_mut(p))
                .zip(losses.iter_mut()),
            |i, (((t, y), g), loss)| {
                let (idx, val) = sparse.row(i);
                *loss = with_ws(|ws| {
                    model.dsgt_node_rule_into(
                        rule,
                        i as u32,
                        idx,
                        val,
                        theta,
                        y_tr,
                        &y_tr[i * p..(i + 1) * p],
                        &g_old[i * p..(i + 1) * p],
                        &bx[i * m * d..(i + 1) * m * d],
                        &by[i * m..(i + 1) * m],
                        lr,
                        t,
                        y,
                        g,
                        ws,
                    )
                });
            },
        );
        Ok(())
    }

    fn dsgd_round_compressed_into(
        &self,
        w: &MixView,
        xhat: &[f32],
        theta: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
        theta_out: &mut [f32],
        losses: &mut [f64],
    ) -> Result<()> {
        let (n, m, p, d) = (self.n, self.m, self.model.p(), self.model.d);
        ensure!(w.sparse.n() == n, "sparse W is {}x, compute wants n={n}", w.sparse.n());
        ensure!(xhat.len() == n * p, "decoded stack size mismatch");
        ensure!(theta_out.len() == n * p && losses.len() == n, "output slab size mismatch");
        let model = &self.model;
        let rule = self.rule;
        let sparse = w.sparse;
        // identical math to the trait default (decoded-stack mix, own
        // full-precision correction, gradient at the node's true row),
        // fanned out over disjoint slab rows
        par_each(
            self.pool(n),
            theta_out.chunks_mut(p).zip(losses.iter_mut()),
            |i, (out, loss)| {
                let (idx, val) = sparse.row(i);
                *loss = with_ws(|ws| {
                    model.dsgd_node_compressed_rule_into(
                        rule,
                        i as u32,
                        idx,
                        val,
                        xhat,
                        &xhat[i * p..(i + 1) * p],
                        &theta[i * p..(i + 1) * p],
                        &bx[i * m * d..(i + 1) * m * d],
                        &by[i * m..(i + 1) * m],
                        lr,
                        out,
                        ws,
                    )
                });
            },
        );
        Ok(())
    }

    fn dsgt_round_compressed_into(
        &self,
        w: &MixView,
        xhat: &[f32],
        yhat: &[f32],
        theta: &[f32],
        y_tr: &[f32],
        g_old: &[f32],
        bx: &[f32],
        by: &[f32],
        lr: f32,
        theta_out: &mut [f32],
        y_out: &mut [f32],
        g_out: &mut [f32],
        losses: &mut [f64],
    ) -> Result<()> {
        let (n, m, p, d) = (self.n, self.m, self.model.p(), self.model.d);
        ensure!(w.sparse.n() == n, "sparse W is {}x, compute wants n={n}", w.sparse.n());
        ensure!(xhat.len() == n * p && yhat.len() == n * p, "decoded stack size mismatch");
        ensure!(
            theta_out.len() == n * p && y_out.len() == n * p && g_out.len() == n * p
                && losses.len() == n,
            "output slab size mismatch"
        );
        let model = &self.model;
        let rule = self.rule;
        let sparse = w.sparse;
        par_each(
            self.pool(n),
            theta_out
                .chunks_mut(p)
                .zip(y_out.chunks_mut(p))
                .zip(g_out.chunks_mut(p))
                .zip(losses.iter_mut()),
            |i, (((t, y), g), loss)| {
                let (idx, val) = sparse.row(i);
                *loss = with_ws(|ws| {
                    model.dsgt_node_compressed_rule_into(
                        rule,
                        i as u32,
                        idx,
                        val,
                        xhat,
                        yhat,
                        &xhat[i * p..(i + 1) * p],
                        &yhat[i * p..(i + 1) * p],
                        &theta[i * p..(i + 1) * p],
                        &y_tr[i * p..(i + 1) * p],
                        &g_old[i * p..(i + 1) * p],
                        &bx[i * m * d..(i + 1) * m * d],
                        &by[i * m..(i + 1) * m],
                        lr,
                        t,
                        y,
                        g,
                        ws,
                    )
                });
            },
        );
        Ok(())
    }

    fn eval_full(&self, theta: &[f32], shards: &[Shard]) -> Result<(f64, f64, f64, f64)> {
        let p = self.model.p();
        let n = shards.len();
        if theta.len() != n * p {
            bail!("eval_full: theta len {} vs {} shards x p={p}", theta.len(), n);
        }
        // per-node partials written into preassigned slots in parallel; the
        // reduction runs serially in node order inside eval_reduce →
        // bitwise-equal to the serial twin
        let mut per: Vec<(f64, Vec<f32>, usize, usize)> = Vec::with_capacity(n);
        per.resize_with(n, || (0.0, Vec::new(), 0, 0));
        let model = &self.model;
        par_each(self.pool(n), shards.iter().zip(per.iter_mut()), |i, (shard, slot)| {
            *slot = model.eval_node(&theta[i * p..(i + 1) * p], shard);
        });
        Ok(self.model.eval_reduce(theta, &per))
    }

    fn predict(&self, theta: &[f32], x: &[f32]) -> Result<Vec<f32>> {
        Ok(self.model.predict(theta, x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn local_steps_all_bails_on_empty_theta() {
        let c = NativeCompute::new(6, 4, 3, 5);
        // the Compute-trait default and the native override must both bail
        // loudly instead of dividing by n = 0 downstream
        let err = c.local_steps_all(&[], &[], &[], &[0.1]).unwrap_err();
        assert!(err.to_string().contains("empty Θ"), "{err}");
        struct DefaultOnly(NativeCompute);
        impl Compute for DefaultOnly {
            fn dims(&self) -> (usize, usize, usize) {
                self.0.dims()
            }
            fn local_steps_len(&self) -> Option<usize> {
                None
            }
            fn grad_step(&self, t: &[f32], x: &[f32], y: &[f32]) -> Result<(f64, Vec<f32>)> {
                self.0.grad_step(t, x, y)
            }
            fn local_steps(
                &self,
                t: &[f32],
                bx: &[f32],
                by: &[f32],
                lrs: &[f32],
            ) -> Result<(Vec<f32>, Vec<f64>)> {
                self.0.local_steps(t, bx, by, lrs)
            }
            fn combine(&self, w: &[f32], t: &[f32]) -> Result<Vec<f32>> {
                self.0.combine(w, t)
            }
            fn dsgd_round(
                &self,
                w: &[f32],
                t: &[f32],
                bx: &[f32],
                by: &[f32],
                lr: f32,
            ) -> Result<(Vec<f32>, Vec<f64>)> {
                self.0.dsgd_round(w, t, bx, by, lr)
            }
            fn dsgt_round(
                &self,
                w: &[f32],
                t: &[f32],
                y: &[f32],
                g: &[f32],
                bx: &[f32],
                by: &[f32],
                lr: f32,
            ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f64>)> {
                self.0.dsgt_round(w, t, y, g, bx, by, lr)
            }
            fn eval_full(&self, t: &[f32], s: &[Shard]) -> Result<(f64, f64, f64, f64)> {
                self.0.eval_full(t, s)
            }
            fn predict(&self, t: &[f32], x: &[f32]) -> Result<Vec<f32>> {
                self.0.predict(t, x)
            }
        }
        let d = DefaultOnly(c);
        let err = d.local_steps_all(&[], &[], &[], &[0.1]).unwrap_err();
        assert!(err.to_string().contains("empty Θ"), "{err}");
    }

    #[test]
    fn cycle_padding_biases_eval_metrics_native_oracle_is_exact() {
        // PjrtCompute::eval_full cycle-pads a shard with sh.n < s.shard rows
        // by row index i % sh.n (see its doc-comment).  Demonstrate the bias
        // arithmetic on the native oracle: pad a 3-row shard to 8 rows —
        // rows 0 and 1 appear 3x, row 2 only 2x — and the padded mean loss
        // is exactly the over-weighted mean (3·l0 + 3·l1 + 2·l2)/8, which
        // differs from the true shard mean (l0 + l1 + l2)/3.  The native
        // backend evaluates the exact shard and is the unbiased reference.
        let model = NativeModel::new(6, 4);
        let mut rng = Pcg64::seed(21);
        let theta = model.init(&mut rng);
        let d = model.d;
        // three well-separated rows so the per-row losses genuinely differ
        let mut x = vec![1.0f32; 3 * d];
        x[d..2 * d].iter_mut().for_each(|v| *v = -1.0);
        x[2 * d..].iter_mut().for_each(|v| *v = 3.0);
        let y = vec![1.0f32, 0.0, 1.0];

        // per-row losses
        let per_row: Vec<f64> = (0..3)
            .map(|i| model.loss_and_grad(&theta, &x[i * d..(i + 1) * d], &y[i..=i]).0)
            .collect();
        let true_mean = per_row.iter().sum::<f64>() / 3.0;

        // cycle-pad to 8 rows exactly as the artifact path does
        let (mut px, mut py) = (Vec::new(), Vec::new());
        for i in 0..8 {
            px.extend_from_slice(&x[(i % 3) * d..(i % 3 + 1) * d]);
            py.push(y[i % 3]);
        }
        let padded = model.loss_and_grad(&theta, &px, &py).0;
        let weighted = (3.0 * per_row[0] + 3.0 * per_row[1] + 2.0 * per_row[2]) / 8.0;
        assert!((padded - weighted).abs() < 1e-9, "padded {padded} vs weighted {weighted}");
        assert!(
            (padded - true_mean).abs() > 1e-6,
            "rows differ, so the padded mean must be biased: {padded} vs {true_mean}"
        );
    }

    #[test]
    fn double_buffered_rounds_bitwise_equal_fresh_vec_path() {
        // run several rounds through the `_into` slabs with swapping (the
        // engine's steady-state path) and through the allocating ops; the
        // trajectories must be bitwise-identical
        let (d, h, n, m, rounds) = (11, 6, 5, 4, 4);
        let c = NativeCompute::new(d, h, n, m).with_threads(1);
        let p = c.dims().2;
        let mut rng = Pcg64::seed(33);
        let mut vec_of = |len: usize, s: f64| -> Vec<f32> {
            (0..len).map(|_| (rng.normal() * s) as f32).collect()
        };
        let theta0 = vec_of(n * p, 0.3);
        let y0 = vec_of(n * p, 0.1);
        let g0 = vec_of(n * p, 0.1);
        let batches: Vec<(Vec<f32>, Vec<f32>)> = (0..rounds)
            .map(|r| {
                let bx = vec_of(n * m * d, 1.0);
                let by = (0..n * m).map(|i| ((i + r) % 2) as f32).collect();
                (bx, by)
            })
            .collect();
        let w = {
            let g = crate::graph::Graph::build(
                &crate::graph::Topology::Ring,
                n,
                &mut Pcg64::seed(1),
            )
            .unwrap();
            crate::mixing::to_f32(&crate::mixing::build(&g, crate::mixing::Scheme::Metropolis))
        };
        let sparse = SparseW::from_dense(n, &w);
        let mix = MixView { dense: Some(&w), sparse: &sparse };

        // DSGD: fresh-Vec vs double-buffered slabs
        let mut ta = theta0.clone();
        for (bx, by) in &batches {
            ta = c.dsgd_round(&w, &ta, bx, by, 0.05).unwrap().0;
        }
        let mut front = theta0.clone();
        let mut back = vec![0.0f32; n * p];
        let mut losses = vec![0.0f64; n];
        for (bx, by) in &batches {
            c.dsgd_round_into(&mix, &front, bx, by, 0.05, &mut back, &mut losses).unwrap();
            std::mem::swap(&mut front, &mut back);
        }
        assert_eq!(ta, front, "dsgd double-buffered trajectory differs");

        // DSGT: three double-buffered stacks
        let (mut ta, mut ya, mut ga) = (theta0.clone(), y0.clone(), g0.clone());
        for (bx, by) in &batches {
            let (t, y, g, _) = c.dsgt_round(&w, &ta, &ya, &ga, bx, by, 0.05).unwrap();
            (ta, ya, ga) = (t, y, g);
        }
        let (mut tf, mut yf, mut gf) = (theta0.clone(), y0, g0);
        let (mut tb, mut yb, mut gb) =
            (vec![0.0f32; n * p], vec![0.0f32; n * p], vec![0.0f32; n * p]);
        for (bx, by) in &batches {
            c.dsgt_round_into(
                &mix, &tf, &yf, &gf, bx, by, 0.05, &mut tb, &mut yb, &mut gb, &mut losses,
            )
            .unwrap();
            std::mem::swap(&mut tf, &mut tb);
            std::mem::swap(&mut yf, &mut yb);
            std::mem::swap(&mut gf, &mut gb);
        }
        assert_eq!(ta, tf, "dsgt θ trajectory differs");
        assert_eq!(ya, yf, "dsgt tracker trajectory differs");
        assert_eq!(ga, gf, "dsgt gradient trajectory differs");

        // local phase slabs round-trip too
        let lrs = vec![0.03f32, 0.02];
        let lx: Vec<f32> = (0..n * 2 * m * d).map(|i| ((i % 7) as f32 - 3.0) * 0.2).collect();
        let ly: Vec<f32> = (0..n * 2 * m).map(|i| (i % 2) as f32).collect();
        let (t1, l1) = c.local_steps_all(&theta0, &lx, &ly, &lrs).unwrap();
        let mut t2 = vec![0.0f32; n * p];
        let mut l2 = vec![0.0f64; n * lrs.len()];
        c.local_steps_all_into(&theta0, &lx, &ly, &lrs, &mut t2, &mut l2).unwrap();
        assert_eq!(t1, t2);
        assert_eq!(l1, l2);
    }

    #[test]
    fn native_compute_roundtrip() {
        let c = NativeCompute::new(6, 4, 3, 5);
        let (d, h, p) = c.dims();
        assert_eq!((d, h), (6, 4));
        assert_eq!(p, 33);
        let mut rng = Pcg64::seed(0);
        let theta: Vec<f32> = (0..p).map(|_| (rng.normal() * 0.2) as f32).collect();
        let x: Vec<f32> = (0..5 * 6).map(|_| rng.normal() as f32).collect();
        let y: Vec<f32> = (0..5).map(|_| if rng.bernoulli(0.5) { 1.0 } else { 0.0 }).collect();
        let (loss, grad) = c.grad_step(&theta, &x, &y).unwrap();
        assert!(loss.is_finite());
        assert_eq!(grad.len(), p);
        // empty local phase is identity
        let (t2, losses) = c.local_steps(&theta, &[], &[], &[]).unwrap();
        assert_eq!(t2, theta);
        assert!(losses.is_empty());
    }
}
