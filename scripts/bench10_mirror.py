#!/usr/bin/env python3
"""Numpy mirror of the PR-10 sharded message pipeline for BENCH_10.json.

The container that grows this repo ships no Rust toolchain, so the frozen
BENCH numbers come from a line-faithful numpy mirror of
``rust/src/engine/shard.rs`` (same convention as BENCH_9.json).  This
script mirrors the PR-10 surface: the quantity-registry slab pool carrying
TEN per-node quantities for a q8 + error-feedback FD-DSGT run
(theta/y/g front+back, decoded X-hat/Y-hat, EF residuals for both
message kinds) and the driver-agnostic message pipeline
(EF accumulate -> q8 encode -> decode -> trimmed-mean combine), sharded
against resident.

Mirrored layout invariants (see DESIGN.md section 15):
  * node-major quantity-minor frames of ``shard_nodes x nq x p`` f32;
  * LRU hot-set with dirty-only write-back through a preallocated staging
    buffer (``pread``/``pwrite``, no mmap, file ftruncate'd so holes read
    zero -- the sparse-file zero-init invariant);
  * halo rows served by single-row pread WITHOUT faulting the neighbor
    shard into the hot set;
  * front/back swap by qmap index permutation, never by copying rows;
  * data streams keyed per ``(seed, block, round, step)`` with a fixed
    block size, so shard boundaries cannot leak into the draw order.

Every per-round operation (keyed draws, per-row q8 with EF, elementwise
median-of-3 trimmed combine) is row-independent, so the sharded sweep is
bitwise-equal to the resident one -- ``selftest`` asserts that across live
LRU evictions.  RNG streams are NOT bit-matched to the crate's Pcg64;
round times are indicative.  The authoritative bitwise contract is
``rust/tests/shard_pins.rs``.

Usage:
  python3 scripts/bench10_mirror.py selftest
  python3 scripts/bench10_mirror.py run --n 1000 --mode sharded --rounds 4
  python3 scripts/bench10_mirror.py run --n 100000 --mode resident --rlimit-mb 1500
"""

import argparse
import json
import os
import resource
import sys
import tempfile
import time

import numpy as np

# Shapes: d=42, hidden=16 MLP -> p = 42*16 + 16 + 16*1 + 1 = 705, matching
# BENCH_9's model so the two freezes compose into one RSS story.
P = 705
BLOCK = 64  # data-stream block; equals shard_nodes so draws never straddle
LOCAL_STEPS = 3
LR = np.float32(0.02)
SEED = 7

# Quantity ids, registration order == physical frame order (QuantitySet
# for a q8+EF FD-DSGT config registers exactly these ten).
TH, TH_B, Y, Y_B, G, G_B, XH, YH, EF_T, EF_Y = range(10)
NQ = 10
NAMES = ["theta", "theta_back", "y", "y_back", "g", "g_back",
         "xhat", "yhat", "ef_t", "ef_y"]


def block_rng(block, rnd, step):
    """Deterministic, shard-oblivious stream for one data block."""
    return np.random.default_rng([SEED, block, rnd, step])


def draw_block(block, rnd, step, k):
    """k gradient rows for data block `block` at (round, step)."""
    return block_rng(block, rnd, step).standard_normal((BLOCK, P), dtype=np.float32)[:k]


def q8_rows(v):
    """Per-row q8: symmetric int8 quantize, dequantized back to f32.

    Row-independent and elementwise, so any row blocking is bitwise-equal.
    """
    a = np.max(np.abs(v), axis=1, keepdims=True)
    scale = a / np.float32(127.0)
    safe = np.where(scale == 0, np.float32(1.0), scale)
    q = np.clip(np.rint(v / safe), np.float32(-127.0), np.float32(127.0))
    return np.where(a == 0, np.float32(0.0), q * safe).astype(np.float32, copy=False)


def encode_rows(x, e):
    """The pipeline's encode_row over a row block: EF accumulate -> q8 ->
    residual update (fully overwrites e, the single-buffer invariant)."""
    v = x + e
    hat = q8_rows(v)
    e[:] = v - hat
    return hat


def combine3(prev_rows, self_rows, next_rows):
    """Trimmed-mean (trim 0.4) over the ring stencil: of 3 values per
    coordinate, drop the min and max -> elementwise median."""
    return np.median(np.stack([prev_rows, self_rows, next_rows]), axis=0).astype(
        np.float32, copy=False
    )


class Pool:
    """Spill-backed slab pool: LRU hot-set, dirty-only write-back, halo
    single-row pread, qmap front/back swap.  Mirrors NodeSlabPool."""

    def __init__(self, n, shard_nodes, hot_shards):
        self.n = n
        self.k = shard_nodes
        self.n_shards = -(-n // shard_nodes)
        self.hot = hot_shards
        self.frames = np.zeros((hot_shards, shard_nodes, NQ, P), dtype=np.float32)
        self.staging = np.empty(shard_nodes * NQ * P, dtype=np.float32)
        self.row_staging = np.empty(P, dtype=np.float32)
        self.frame_bytes = self.staging.nbytes
        self.owner = [None] * hot_shards          # frame -> shard
        self.where = [None] * self.n_shards       # shard -> frame
        self.dirty = [False] * hot_shards
        self.lru = []                             # frame indices, LRU first
        self.qmap = list(range(NQ))
        fd, path = tempfile.mkstemp(prefix="decfl-mirror-")
        os.unlink(path)
        os.ftruncate(fd, self.n_shards * self.frame_bytes)  # holes read zero
        self.fd = fd
        self.loads = self.spills = self.writebacks = self.hits = 0

    def close(self):
        os.close(self.fd)

    def _touch(self, f):
        self.lru.remove(f)
        self.lru.append(f)

    def acquire(self, shard):
        f = self.where[shard]
        if f is not None:
            self.hits += 1
            self._touch(f)
            return f
        if len(self.lru) < self.hot:
            f = len(self.lru)
            self.lru.append(f)
        else:
            f = self.lru[0]
            old = self.owner[f]
            if self.dirty[f]:
                self.staging[:] = self.frames[f].reshape(-1)
                os.pwrite(self.fd, self.staging.data, old * self.frame_bytes)
                self.writebacks += 1
            self.spills += 1
            self.where[old] = None
            self._touch(f)
        got = os.preadv(self.fd, [self.staging.data], shard * self.frame_bytes)
        assert got == self.frame_bytes
        self.frames[f] = self.staging.reshape(self.k, NQ, P)
        self.loads += 1
        self.owner[f] = shard
        self.where[shard] = f
        self.dirty[f] = False
        return f

    def rows(self, shard, q):
        """(k, P) view of logical quantity q in the (hot) shard's frame."""
        f = self.acquire(shard)
        lo = shard * self.k
        k = min(self.n, lo + self.k) - lo
        return self.frames[f][:k, self.qmap[q], :]

    def mark_dirty(self, shard):
        self.dirty[self.where[shard]] = True

    def read_row(self, node, q, out):
        """Halo read: hot frame if present, else one pread -- never faults
        the neighbor's shard into the hot set."""
        shard, local = divmod(node, self.k)
        f = self.where[shard]
        if f is not None:
            self.hits += 1
            out[:] = self.frames[f][local, self.qmap[q], :]
            return
        off = shard * self.frame_bytes + (local * NQ + self.qmap[q]) * P * 4
        got = os.preadv(self.fd, [out.data], off)
        assert got == P * 4
        self.loads += 1

    def swap(self, a, b):
        self.qmap[a], self.qmap[b] = self.qmap[b], self.qmap[a]

    def stats(self):
        return {"loads": self.loads, "spills": self.spills,
                "writebacks": self.writebacks, "hits": self.hits}


def run_resident(n, rounds):
    """Resident stacks, identical math, block-keyed draws."""
    q = [np.zeros((n, P), dtype=np.float32) for _ in range(NQ)]
    for b in range(-(-n // BLOCK)):
        lo, hi = b * BLOCK, min(n, (b + 1) * BLOCK)
        q[TH][lo:hi] = draw_block(b, 0, 0, hi - lo)
    times = []
    for rnd in range(1, rounds + 1):
        t0 = time.perf_counter()
        for step in range(LOCAL_STEPS):
            for b in range(-(-n // BLOCK)):
                lo, hi = b * BLOCK, min(n, (b + 1) * BLOCK)
                gr = draw_block(b, rnd, step, hi - lo)
                q[TH][lo:hi] -= LR * gr
                q[Y][lo:hi] += gr - q[G][lo:hi]
                q[G][lo:hi] = gr
        q[XH][:] = encode_rows(q[TH], q[EF_T])
        q[YH][:] = encode_rows(q[Y], q[EF_Y])
        for b in range(-(-n // BLOCK)):  # blockwise: bound the transients
            lo, hi = b * BLOCK, min(n, (b + 1) * BLOCK)
            idx = np.arange(lo, hi)
            for src, dst in ((XH, TH_B), (YH, Y_B)):
                q[dst][lo:hi] = combine3(
                    q[src][(idx - 1) % n], q[src][lo:hi], q[src][(idx + 1) % n]
                )
        q[TH], q[TH_B] = q[TH_B], q[TH]
        q[Y], q[Y_B] = q[Y_B], q[Y]
        times.append(time.perf_counter() - t0)
    return q[TH], times, {"loads": 0, "spills": 0, "writebacks": 0, "hits": 0}


def checksum(rows_of):
    """Order-pinned fleet checksum: f64 per-block sums added in block
    order, identical between layouts without materializing (n, P)."""
    total = 0.0
    b = 0
    while True:
        rows = rows_of(b)
        if rows is None:
            return total
        total += float(rows.astype(np.float64).sum())
        b += 1


def run_sharded(n, rounds, hot_shards):
    """The sharded sweep through the pool: local, encode, combine-with-halo."""
    pool = Pool(n, BLOCK, hot_shards)
    for s in range(pool.n_shards):
        lo = s * BLOCK
        pool.rows(s, TH)[:] = draw_block(s, 0, 0, min(n, lo + BLOCK) - lo)
        pool.mark_dirty(s)
    prev = np.empty(P, dtype=np.float32)
    nxt = np.empty(P, dtype=np.float32)
    times = []
    for rnd in range(1, rounds + 1):
        t0 = time.perf_counter()
        for s in range(pool.n_shards):  # local phase
            lo = s * BLOCK
            k = min(n, lo + BLOCK) - lo
            th, y, g = pool.rows(s, TH), pool.rows(s, Y), pool.rows(s, G)
            for step in range(LOCAL_STEPS):
                gr = draw_block(s, rnd, step, k)
                th -= LR * gr
                y += gr - g
                g[:] = gr
            pool.mark_dirty(s)
        for s in range(pool.n_shards):  # encode sweep
            pool.rows(s, XH)[:] = encode_rows(pool.rows(s, TH), pool.rows(s, EF_T))
            pool.rows(s, YH)[:] = encode_rows(pool.rows(s, Y), pool.rows(s, EF_Y))
            pool.mark_dirty(s)
        for s in range(pool.n_shards):  # combine sweep with halo reads
            lo = s * BLOCK
            k = min(n, lo + BLOCK) - lo
            for src, dst in ((XH, TH_B), (YH, Y_B)):
                rows = pool.rows(s, src)
                pool.read_row((lo - 1) % n, src, prev)
                pool.read_row((lo + k) % n, src, nxt)
                p_rows = np.concatenate([prev[None, :], rows[:-1]])
                n_rows = np.concatenate([rows[1:], nxt[None, :]])
                pool.rows(s, dst)[:] = combine3(p_rows, rows, n_rows)
            pool.mark_dirty(s)
        pool.swap(TH, TH_B)
        pool.swap(Y, Y_B)
        times.append(time.perf_counter() - t0)
    return pool, times, pool.stats()


def cmd_run(args):
    if args.rlimit_mb:
        lim = args.rlimit_mb << 20
        resource.setrlimit(resource.RLIMIT_AS, (lim, lim))
    try:
        if args.mode == "resident":
            theta, times, stats = run_resident(args.n, args.rounds)

            def rows_of(b):
                lo = b * BLOCK
                return None if lo >= args.n else theta[lo : min(args.n, lo + BLOCK)]

        else:
            pool, times, stats = run_sharded(args.n, args.rounds, args.hot_shards)

            def rows_of(b):
                return None if b >= pool.n_shards else pool.rows(b, TH)

    except MemoryError:
        print(json.dumps({"n": args.n, "mode": args.mode, "oom": True}))
        return
    total = checksum(rows_of)
    rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0
    print(json.dumps({
        "n": args.n, "mode": args.mode, "oom": False,
        "rounds": args.rounds, "round_s": sum(times) / len(times),
        "peak_rss_mb": round(rss_mb, 1), "theta_sum": total,
        **stats,
    }))


def cmd_selftest(args):
    n, rounds = 512, 3
    rt, _, _ = run_resident(n, rounds)
    pool, _, ss = run_sharded(n, rounds, 2)
    # .copy() inside the comprehension: rows() returns a frame view, and a
    # later acquire may reuse that frame before concatenate reads it
    st = np.concatenate([pool.rows(s, TH).copy() for s in range(pool.n_shards)])
    pool.close()
    bitwise = bool(np.array_equal(rt.view(np.uint32), st.view(np.uint32)))
    print(json.dumps({
        "n": n, "rounds": rounds, "final_theta_bitwise": bitwise,
        "max_abs_diff": float(np.max(np.abs(rt - st))),
        "pool_loads": ss["loads"], "pool_spills": ss["spills"],
        "pool_writebacks": ss["writebacks"],
    }))
    sys.exit(0 if bitwise else 1)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)
    r = sub.add_parser("run", help="one measured child run")
    r.add_argument("--n", type=int, required=True)
    r.add_argument("--mode", choices=["resident", "sharded"], required=True)
    r.add_argument("--rounds", type=int, default=2)
    r.add_argument("--hot-shards", type=int, default=4)
    r.add_argument("--rlimit-mb", type=int, default=0)
    r.set_defaults(fn=cmd_run)
    s = sub.add_parser("selftest", help="sharded == resident bitwise check")
    s.set_defaults(fn=cmd_selftest)
    args = ap.parse_args()
    args.fn(args)


if __name__ == "__main__":
    main()
