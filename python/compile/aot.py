"""AOT compile path: lower the L2 model to HLO-text artifacts + manifest.

Run once by ``make artifacts``:

    cd python && python -m compile.aot --out ../artifacts

Emits one shape-specialized ``<name>.hlo.txt`` per artifact in DESIGN.md §4
plus ``manifest.json`` describing shapes and *golden values* — outputs of each
artifact on deterministic pseudo-random inputs that the rust integration
tests regenerate bit-identically (integer-hash inputs, see ``golden_val``)
and compare against after executing the compiled HLO through PJRT.

Interchange is HLO **text**: jax >= 0.5 serializes HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 rejects; the text parser reassigns
ids and round-trips cleanly (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---- deterministic golden inputs (mirrored by rust/src/runtime/golden.rs) ----


def golden_vec(offset: int, count: int, scale: float) -> np.ndarray:
    """Knuth-hash pseudo-random f32 vector, exactly reproducible in rust.

    v[i] = ((((offset+i+1) * 2654435761) mod 2^32) / 2^32 - 0.5) * scale
    computed in f64, cast to f32.
    """
    idx = np.arange(offset + 1, offset + count + 1, dtype=np.uint64)
    hashed = (idx * np.uint64(2654435761)) % np.uint64(2**32)
    return ((hashed.astype(np.float64) / 2.0**32 - 0.5) * scale).astype(np.float32)


def golden_labels(offset: int, count: int) -> np.ndarray:
    """y[i] = bit0 of the same hash — {0.0, 1.0} labels."""
    idx = np.arange(offset + 1, offset + count + 1, dtype=np.uint64)
    hashed = (idx * np.uint64(2654435761)) % np.uint64(2**32)
    return (hashed & np.uint64(1)).astype(np.float32)


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def lower_artifacts(n: int, d: int, h: int, m: int, q: int, shard: int):
    """Build {name: (lowered, input_shapes, output_shapes)} for all artifacts."""
    p = model.param_count(d, h)

    def jl(fn, *specs):
        return jax.jit(fn).lower(*specs)

    arts = {}

    arts["grad_step"] = (
        jl(lambda t, x, y: model.loss_and_grad(t, x, y, d, h), spec(p), spec(m, d), spec(m)),
        [[p], [m, d], [m]],
        [[], [p]],
    )
    # Algorithm 1 round structure: Q-1 local updates (eq. 4), then one
    # communication update (eq. 2/3) which consumes its own gradient — so the
    # local-phase artifact scans Q-1 steps (see rust algo::RoundPlan).
    ql = max(q - 1, 1)
    arts["local_steps"] = (
        jl(
            lambda t, bx, by, lrs: model.local_steps(t, bx, by, lrs, d, h),
            spec(p), spec(ql, m, d), spec(ql, m), spec(ql),
        ),
        [[p], [ql, m, d], [ql, m], [ql]],
        [[p], [ql]],
    )
    arts["local_steps_all"] = (
        jl(
            lambda th, bx, by, lrs: model.local_steps_all(th, bx, by, lrs, d, h),
            spec(n, p), spec(n, ql, m, d), spec(n, ql, m), spec(ql),
        ),
        [[n, p], [n, ql, m, d], [n, ql, m], [ql]],
        [[n, p], [n, ql]],
    )
    arts["combine"] = (
        jl(model.combine, spec(n), spec(n, p)),
        [[n], [n, p]],
        [[p]],
    )
    arts["dsgd_round"] = (
        jl(
            lambda w, th, bx, by, lr: model.dsgd_round(w, th, bx, by, lr, d, h),
            spec(n, n), spec(n, p), spec(n, m, d), spec(n, m), spec(),
        ),
        [[n, n], [n, p], [n, m, d], [n, m], []],
        [[n, p], [n]],
    )
    arts["dsgt_round"] = (
        jl(
            lambda w, th, ytr, g, bx, by, lr: model.dsgt_round(w, th, ytr, g, bx, by, lr, d, h),
            spec(n, n), spec(n, p), spec(n, p), spec(n, p), spec(n, m, d), spec(n, m), spec(),
        ),
        [[n, n], [n, p], [n, p], [n, p], [n, m, d], [n, m], []],
        [[n, p], [n, p], [n, p], [n]],
    )
    # masked eval: the 4th input flags real (1.0) vs cycle-padded (0.0) rows,
    # so uneven shards evaluate exactly (record-weighted loss/accuracy; see
    # rust PjrtCompute::eval_full)
    arts["eval_full"] = (
        jl(
            lambda th, xs, ys, mask: model.eval_full(th, xs, ys, mask, d, h),
            spec(n, p), spec(n, shard, d), spec(n, shard), spec(n, shard),
        ),
        [[n, p], [n, shard, d], [n, shard], [n, shard]],
        [[], [], [], []],
    )
    arts["predict"] = (
        jl(lambda t, x: model.predict(t, x, d, h), spec(p), spec(shard, d)),
        [[p], [shard, d]],
        [[shard]],
    )
    return arts, p


def compute_goldens(n: int, d: int, h: int, m: int, q: int, p: int):
    """Run (jit, not the HLO files) each artifact on golden inputs; record
    scalars the rust side asserts after executing the *compiled artifacts*
    on identical inputs."""
    theta = jnp.asarray(golden_vec(0, p, 0.2))
    x = jnp.asarray(golden_vec(p, m * d, 2.0).reshape(m, d))
    y = jnp.asarray(golden_labels(p + m * d, m))

    loss, grad = jax.jit(lambda t, xx, yy: model.loss_and_grad(t, xx, yy, d, h))(theta, x, y)

    wrow = np.full((n,), 1.0 / n, dtype=np.float32)
    big = jnp.asarray(golden_vec(1000, n * p, 0.2).reshape(n, p))
    comb = jax.jit(model.combine)(jnp.asarray(wrow), big)

    ql = max(q - 1, 1)  # matches the local_steps artifact shape
    bx = jnp.asarray(golden_vec(2000, ql * m * d, 2.0).reshape(ql, m, d))
    by = jnp.asarray(golden_labels(2000 + ql * m * d, ql * m).reshape(ql, m))
    lrs = jnp.asarray((0.02 / np.sqrt(np.arange(1, ql + 1))).astype(np.float32))
    t_out, losses = jax.jit(
        lambda t, a, b, c: model.local_steps(t, a, b, c, d, h)
    )(theta, bx, by, lrs)

    return {
        "grad_step": {
            "loss": float(loss),
            "grad_norm": float(jnp.linalg.norm(grad)),
            "grad_head": [float(v) for v in grad[:4]],
        },
        "combine": {
            "out_norm": float(jnp.linalg.norm(comb)),
            "out_head": [float(v) for v in comb[:4]],
        },
        "local_steps": {
            "theta_norm": float(jnp.linalg.norm(t_out)),
            "loss_first": float(losses[0]),
            "loss_last": float(losses[-1]),
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output dir")
    ap.add_argument("--n", type=int, default=20, help="number of hospital nodes")
    ap.add_argument("--d", type=int, default=42, help="feature dimension (paper: 42)")
    ap.add_argument("--hidden", type=int, default=32, help="MLP hidden width")
    ap.add_argument("--m", type=int, default=20, help="minibatch size (paper: 20)")
    ap.add_argument("--q", type=int, default=100, help="local steps per comm round (paper: 100)")
    ap.add_argument("--shard", type=int, default=500, help="per-node records (paper: ~500)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    arts, p = lower_artifacts(args.n, args.d, args.hidden, args.m, args.q, args.shard)

    manifest = {
        "version": 1,
        "config": {
            "n": args.n, "d": args.d, "hidden": args.hidden,
            "m": args.m, "q": args.q, "shard": args.shard, "p": p,
        },
        "artifacts": {},
        "goldens": compute_goldens(args.n, args.d, args.hidden, args.m, args.q, p),
    }

    for name, (lowered, in_shapes, out_shapes) in arts.items():
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": in_shapes,
            "outputs": out_shapes,
        }
        print(f"  {name:12s} -> {fname} ({len(text)} chars)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out}/manifest.json (P = {p})")


if __name__ == "__main__":
    main()
