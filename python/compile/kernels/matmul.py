"""L1 Pallas kernel: tiled matmul with a custom VJP.

This is the compute hot-spot of the paper's workload: every node's shallow-MLP
forward/backward is two matmuls, and the gossip mixing step ``W @ Theta`` is a
third (see ``mix.py``).

TPU shaping
-----------
The kernel follows the canonical MXU-friendly schedule: a 3-d grid over
``(rows, cols, contraction)`` tiles, each grid step loading an
``(bm, bk)`` block of ``x`` and a ``(bk, bn)`` block of ``w`` into VMEM and
accumulating ``x_blk @ w_blk`` into the output block in f32.  Block sizes are
rounded to the f32 VPU/MXU tile quanta (sublane 8, lane 128).  Inputs whose
dimensions are not multiples of the chosen blocks are zero-padded by the
wrapper and the result is sliced back — zero padding is exact for matmul.

The kernel is always lowered with ``interpret=True``: the CPU PJRT plugin
(xla_extension 0.5.1) cannot execute Mosaic custom-calls, and interpret mode
lowers to plain HLO which the rust runtime runs unmodified.  On a real TPU the
same BlockSpecs compile to an MXU pipeline; DESIGN.md §7 and EXPERIMENTS.md
estimate the VMEM footprint / MXU utilization for the default shapes.

Autodiff
--------
Pallas calls do not support reverse-mode AD in interpret mode, so ``matmul``
carries a ``custom_vjp`` whose forward and backward passes are the same tiled
kernel (``dx = g @ w.T``, ``dw = x.T @ g``).  This keeps the *entire* MLP
backward pass inside Pallas kernels — nothing falls back to XLA dot except
the scalar glue.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# f32 tile quanta on TPU: (sublane, lane) = (8, 128).
_SUBLANE = 8
_LANE = 128

# Default VMEM budget guard: max elements held per grid step
# (x block + w block + o block), in f32.  16 MiB VMEM / 4 B = 4 Mi elements;
# stay well under with <= 256 Ki elements per step.
_DEFAULT_BM = 128
_DEFAULT_BN = 128
_DEFAULT_BK = 256


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def block_shape(m: int, k: int, n: int) -> tuple[int, int, int]:
    """Pick (bm, bk, bn) for an (m, k) x (k, n) matmul.

    Small dimensions get a single tile rounded to the hardware quantum so the
    grid collapses; large dimensions use the default MXU-sized blocks.
    """
    bm = min(_DEFAULT_BM, _round_up(m, _SUBLANE))
    bn = min(_DEFAULT_BN, _round_up(n, _LANE))
    bk = min(_DEFAULT_BK, _round_up(k, _LANE))
    return bm, bk, bn


def vmem_bytes(m: int, k: int, n: int) -> int:
    """Estimated VMEM bytes resident per grid step (f32)."""
    bm, bk, bn = block_shape(m, k, n)
    return 4 * (bm * bk + bk * bn + bm * bn)


def _mm_kernel(x_ref, w_ref, o_ref, *, nk: int):
    """One grid step: accumulate an (bm, bk) @ (bk, bn) product into o."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )


def _mm_raw(x: jax.Array, w: jax.Array) -> jax.Array:
    """Tiled pallas matmul on padded inputs (shapes already block multiples)."""
    m, k = x.shape
    _, n = w.shape
    bm, bk, bn = block_shape(m, k, n)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        functools.partial(_mm_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def _mm(x: jax.Array, w: jax.Array) -> jax.Array:
    """Pad to block multiples, run the tiled kernel, slice back."""
    m, k = x.shape
    k2, n = w.shape
    if k != k2:
        raise ValueError(f"matmul contraction mismatch: {x.shape} @ {w.shape}")
    bm, bk, bn = block_shape(m, k, n)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))
    return _mm_raw(xp, wp)[:m, :n]


@jax.custom_vjp
def matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """``x @ w`` as a tiled Pallas kernel, differentiable (custom VJP)."""
    return _mm(x, w)


def _matmul_fwd(x, w):
    return _mm(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    return _mm(g, w.T), _mm(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)
