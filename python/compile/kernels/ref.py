"""Pure-jnp oracles for the Pallas kernels and the L2 model.

Everything here is the straightforward textbook computation with no tiling,
padding, or pallas involvement.  pytest compares every kernel and every model
function against these references — this file is the correctness ground truth
for the whole compile path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))


def ref_bmm(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.einsum("bmk,bkn->bmn", x.astype(jnp.float32), w.astype(jnp.float32))


def ref_mix_all(w: jax.Array, theta: jax.Array) -> jax.Array:
    return jnp.dot(w.astype(jnp.float32), theta.astype(jnp.float32))


def ref_mix_row(wrow: jax.Array, theta: jax.Array) -> jax.Array:
    return jnp.dot(wrow.astype(jnp.float32), theta.astype(jnp.float32))


# ---- model oracle (flat-parameter shallow MLP, logistic loss) ----


def ref_unflatten(theta: jax.Array, d: int, h: int):
    i0 = d * h
    w1 = theta[:i0].reshape(d, h)
    b1 = theta[i0 : i0 + h]
    w2 = theta[i0 + h : i0 + 2 * h].reshape(h, 1)
    b2 = theta[i0 + 2 * h :]
    return w1, b1, w2, b2


def ref_logits(theta: jax.Array, x: jax.Array, d: int, h: int) -> jax.Array:
    w1, b1, w2, b2 = ref_unflatten(theta, d, h)
    hid = jnp.tanh(jnp.dot(x, w1) + b1)
    return (jnp.dot(hid, w2) + b2)[:, 0]


def ref_loss(theta: jax.Array, x: jax.Array, y: jax.Array, d: int, h: int) -> jax.Array:
    """Mean logistic loss, labels y in {0, 1}."""
    z = ref_logits(theta, x, d, h)
    return jnp.mean(jnp.logaddexp(0.0, z) - y * z)


def ref_loss_and_grad(theta, x, y, d: int, h: int):
    return jax.value_and_grad(lambda t: ref_loss(t, x, y, d, h))(theta)


def ref_local_steps(theta, bx, by, lrs, d: int, h: int):
    """Q plain SGD steps (paper eq. 4), returning final params and per-step loss."""
    losses = []
    for q in range(bx.shape[0]):
        loss, g = ref_loss_and_grad(theta, bx[q], by[q], d, h)
        theta = theta - lrs[q] * g
        losses.append(loss)
    return theta, jnp.stack(losses)


def ref_dsgd_round(w, big_theta, bx, by, lr, d: int, h: int):
    """Paper eq. 2 applied to every node (stacked)."""
    n = big_theta.shape[0]
    losses, grads = [], []
    for i in range(n):
        loss, g = ref_loss_and_grad(big_theta[i], bx[i], by[i], d, h)
        losses.append(loss)
        grads.append(g)
    g = jnp.stack(grads)
    theta_next = jnp.dot(w, big_theta) - lr * g
    return theta_next, jnp.stack(losses)


def ref_dsgt_round(w, big_theta, y_tr, g_old, bx, by, lr, d: int, h: int):
    """Paper eq. 3 applied to every node (stacked)."""
    theta_next = jnp.dot(w, big_theta) - lr * y_tr
    n = big_theta.shape[0]
    losses, grads = [], []
    for i in range(n):
        loss, g = ref_loss_and_grad(theta_next[i], bx[i], by[i], d, h)
        losses.append(loss)
        grads.append(g)
    g_new = jnp.stack(grads)
    y_next = jnp.dot(w, y_tr) + g_new - g_old
    return theta_next, y_next, g_new, jnp.stack(losses)


def ref_eval_full(big_theta, xs, ys, mask, d: int, h: int):
    """(record-weighted loss, record-weighted accuracy, stationarity gap,
    consensus error).

    The straightforward oracle for the masked artifact: per node, keep only
    the rows whose ``mask`` entry is 1.0 (concrete boolean indexing — this
    runs outside jit), take that node's exact mean loss/gradient, then weight
    loss and accuracy by true record counts while the Theorem-1 terms stay
    node means.
    """
    n = big_theta.shape[0]
    losses, grads, corrects, counts = [], [], [], []
    for i in range(n):
        keep = mask[i] > 0.0
        xi, yi = xs[i][keep], ys[i][keep]
        loss, g = ref_loss_and_grad(big_theta[i], xi, yi, d, h)
        z = ref_logits(big_theta[i], xi, d, h)
        corrects.append(jnp.sum(((z > 0).astype(jnp.float32) == yi).astype(jnp.float32)))
        counts.append(yi.shape[0])
        losses.append(loss)
        grads.append(g)
    counts = jnp.asarray(counts, dtype=jnp.float32)
    total = jnp.sum(counts)
    mean_grad = jnp.mean(jnp.stack(grads), axis=0)
    stat = jnp.sum(mean_grad**2)
    theta_bar = jnp.mean(big_theta, axis=0)
    cons = jnp.mean(jnp.sum((big_theta - theta_bar) ** 2, axis=1))
    return (
        jnp.sum(jnp.stack(losses) * counts) / total,
        jnp.sum(jnp.stack(corrects)) / total,
        stat,
        cons,
    )
