"""L1 Pallas kernel: gossip mixing ``W @ Theta`` and per-node row mixing.

The decentralized comm step (paper eqs. 2–3) combines neighbor parameters with
the mixing-matrix weights.  Stacking node parameters as ``Theta in R^{N x P}``
this is a *skinny* matmul: N is tiny (20 hospitals) while P is the flat
parameter count, so the schedule tiles only the P axis and keeps the whole
N x N weight block resident in VMEM.

``mix_all``  : (W [N,N], Theta [N,P])   -> W @ Theta       (fused fast path)
``mix_row``  : (w [N],   Theta [N,P])   -> sum_j w_j Theta_j (actor mode — one
               node combining the neighborhood it received over the netsim)

Both are exact for zero padding, which the wrappers use to reach tile quanta.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _round_up

_SUBLANE = 8
_LANE = 128
# P-axis tile: one grid step holds (Np x Np) + 2 * (Np x BP) f32 blocks in
# VMEM; BP = 512 keeps that < 0.5 MiB for N <= 64.
_BP = 512


def _mix_kernel(w_ref, t_ref, o_ref):
    o_ref[...] = jnp.dot(w_ref[...], t_ref[...], preferred_element_type=jnp.float32)


def _mix_padded(w: jax.Array, theta: jax.Array) -> jax.Array:
    """(Mp, Np) @ (Np, Pp) with the P axis gridded; shapes pre-padded."""
    mp, np_ = w.shape
    _, pp = theta.shape
    bp = min(_BP, pp)
    grid = (pp // bp,)
    return pl.pallas_call(
        _mix_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((mp, np_), lambda j: (0, 0)),
            pl.BlockSpec((np_, bp), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((mp, bp), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((mp, pp), jnp.float32),
        interpret=True,
    )(w, theta)


def mix_all(w: jax.Array, theta: jax.Array) -> jax.Array:
    """``W @ Theta`` for the whole network in one kernel launch."""
    n, n2 = w.shape
    n3, p = theta.shape
    if n != n2 or n != n3:
        raise ValueError(f"mix_all shape mismatch: W {w.shape}, Theta {theta.shape}")
    npad = _round_up(n, _SUBLANE)
    bp = min(_BP, _round_up(p, _LANE))
    ppad = _round_up(p, bp)
    wp = jnp.pad(w.astype(jnp.float32), ((0, npad - n), (0, npad - n)))
    tp = jnp.pad(theta.astype(jnp.float32), ((0, npad - n), (0, ppad - p)))
    return _mix_padded(wp, tp)[:n, :p]


def mix_row(wrow: jax.Array, theta: jax.Array) -> jax.Array:
    """One node's combine: ``sum_j w_j Theta_j`` (eq. 2/3 left term)."""
    (n,) = wrow.shape
    n2, p = theta.shape
    if n != n2:
        raise ValueError(f"mix_row shape mismatch: w {wrow.shape}, Theta {theta.shape}")
    npad = _round_up(n, _SUBLANE)
    bp = min(_BP, _round_up(p, _LANE))
    ppad = _round_up(p, bp)
    wp = jnp.pad(wrow.astype(jnp.float32)[None, :], ((0, _SUBLANE - 1), (0, npad - n)))
    tp = jnp.pad(theta.astype(jnp.float32), ((0, npad - n), (0, ppad - p)))
    return _mix_padded(wp, tp)[0, :p]
