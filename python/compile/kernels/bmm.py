"""L1 Pallas kernel: batched matmul with the *batch-in-block* schedule.

The whole-network artifacts (``dsgd_round``, ``dsgt_round``, ``eval_full``,
``local_steps_all``) compute every hospital's MLP forward/backward in one
call: ``X [N,m,d] @ W1 [N,d,h]`` — a batched matmul.  Two schedules were
measured (EXPERIMENTS.md §Perf):

* **grid-over-batch** (one grid step per node, or vmap of the 2-d kernel):
  interpret-mode grid iteration costs ~1.5 ms per step on CPU-PJRT, so a
  20-node round paid ~30 ms in grid overhead alone;
* **batch-in-block** (this kernel): the entire padded batch lives in one
  block — VMEM per grid step is ``bb * (bm*bk + bk*bn + bm*bn) * 4`` bytes,
  ≈ 1.6 MiB for the paper shapes (20, 24, 128) × (20, 128, 128), far under
  the 16 MiB budget — so a full round is a handful of grid steps.  11×
  faster end to end on this testbed, and on a real TPU the same BlockSpec
  keeps the MXU fed with back-to-back (bm×bk)·(bk×bn) tiles per batch lane.

The k-axis still tiles (accumulating in the output block) so large
contractions stay within VMEM.  Zero padding everywhere is exact for matmul.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _round_up

_SUBLANE = 8
_LANE = 128
# batch lanes per block: 32 covers the paper's N=20 in one grid step while
# keeping the block set < 4 MiB for the default tile sizes.
_BB = 32
_BM = 128
_BN = 128
_BK = 256


def block_shape_batched(b: int, m: int, k: int, n: int) -> tuple[int, int, int, int]:
    """(bb, bm, bk, bn) for a [b,m,k] x [b,k,n] batched matmul."""
    bm = min(_BM, _round_up(m, _SUBLANE))
    bn = min(_BN, _round_up(n, _LANE))
    bk = min(_BK, _round_up(k, _LANE))
    bb = min(_BB, b)
    return bb, bm, bk, bn


def vmem_bytes_batched(b: int, m: int, k: int, n: int) -> int:
    """Estimated VMEM bytes resident per grid step (f32)."""
    bb, bm, bk, bn = block_shape_batched(b, m, k, n)
    return 4 * bb * (bm * bk + bk * bn + bm * bn)


def _bmm_kernel(x_ref, w_ref, o_ref, *, nk: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jax.lax.dot_general(
        x_ref[...],
        w_ref[...],
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )


def _bmm(x: jax.Array, w: jax.Array) -> jax.Array:
    b, m, k = x.shape
    b2, k2, n = w.shape
    if b != b2 or k != k2:
        raise ValueError(f"bmm shape mismatch: {x.shape} @ {w.shape}")
    bb, bm, bk, bn = block_shape_batched(b, m, k, n)
    bp = _round_up(b, bb)
    mp, kp, np_ = _round_up(m, bm), _round_up(k, bk), _round_up(n, bn)
    xp = jnp.pad(x.astype(jnp.float32), ((0, bp - b), (0, mp - m), (0, kp - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, bp - b), (0, kp - k), (0, np_ - n)))
    grid = (bp // bb, mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_bmm_kernel, nk=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bm, bk), lambda b_, i, j, k_: (b_, i, k_)),
            pl.BlockSpec((bb, bk, bn), lambda b_, i, j, k_: (b_, k_, j)),
        ],
        out_specs=pl.BlockSpec((bb, bm, bn), lambda b_, i, j, k_: (b_, i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:b, :m, :n]


@jax.custom_vjp
def bmm(x: jax.Array, w: jax.Array) -> jax.Array:
    """Batched ``x @ w`` over the leading axis, differentiable (custom VJP)."""
    return _bmm(x, w)


def _bmm_fwd(x, w):
    return _bmm(x, w), (x, w)


def _bmm_bwd(res, g):
    x, w = res
    return _bmm(g, jnp.swapaxes(w, 1, 2)), _bmm(jnp.swapaxes(x, 1, 2), g)


bmm.defvjp(_bmm_fwd, _bmm_bwd)
