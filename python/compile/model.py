"""L2: the paper's model and update rules as jax functions over flat params.

Every function here operates on *flat* f32 parameter vectors (``theta`` of
length ``P = d*h + 2h + 1``) because the object the decentralized algorithms
gossip is the flat vector — mixing is a matrix product over ``Theta`` in
``R^{N x P}``.  All matrix products route through the L1 Pallas kernels
(``kernels.matmul`` / ``kernels.mix``), so the AOT-lowered HLO exercises the
kernel schedule end to end.

The model is the paper's "shallow neural network" per node: a 1-hidden-layer
MLP (tanh) with logistic loss for the AD-vs-MCI binary classification, input
dimension 42 (paper §3).

These functions are lowered once by ``aot.py`` into shape-specialized HLO
artifacts; python never runs on the training path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.bmm import bmm
from .kernels.matmul import matmul
from .kernels.mix import mix_all, mix_row


def param_count(d: int, h: int) -> int:
    """Flat parameter count of the d -> h -> 1 MLP."""
    return d * h + h + h + 1


def unflatten(theta: jax.Array, d: int, h: int):
    """Split the flat vector into (W1 [d,h], b1 [h], W2 [h,1], b2 [1])."""
    i0 = d * h
    w1 = theta[:i0].reshape(d, h)
    b1 = theta[i0 : i0 + h]
    w2 = theta[i0 + h : i0 + 2 * h].reshape(h, 1)
    b2 = theta[i0 + 2 * h :]
    return w1, b1, w2, b2


def logits(theta: jax.Array, x: jax.Array, d: int, h: int) -> jax.Array:
    """Forward pass -> raw logits [batch]."""
    w1, b1, w2, b2 = unflatten(theta, d, h)
    hid = jnp.tanh(matmul(x, w1) + b1)
    return (matmul(hid, w2) + b2)[:, 0]


def loss(theta: jax.Array, x: jax.Array, y: jax.Array, d: int, h: int) -> jax.Array:
    """Mean logistic loss; labels y in {0, 1} (1 = AD, 0 = MCI)."""
    z = logits(theta, x, d, h)
    return jnp.mean(jnp.logaddexp(0.0, z) - y * z)


def loss_and_grad(theta, x, y, d: int, h: int):
    """(loss, grad) — one stochastic gradient (the ``grad_step`` artifact)."""
    return jax.value_and_grad(lambda t: loss(t, x, y, d, h))(theta)


def predict(theta, x, d: int, h: int) -> jax.Array:
    """P(AD | x) per row (the ``predict`` artifact, used for test-set AUC)."""
    return jax.nn.sigmoid(logits(theta, x, d, h))


def local_steps(theta, bx, by, lrs, d: int, h: int):
    """Paper eq. (4), Q times, inside one ``lax.scan``.

    bx [Q,m,d] / by [Q,m] are the pre-sampled minibatches for the Q local
    updates, lrs [Q] the per-step learning rates (the coordinator implements
    the paper's alpha_r = alpha0/sqrt(r) schedule).  One PJRT call per Q
    steps instead of Q calls — the key L2 perf decision.
    """

    def step(t, qb):
        qx, qy, lr = qb
        l, g = jax.value_and_grad(lambda tt: loss(tt, qx, qy, d, h))(t)
        return t - lr * g, l

    theta_out, losses = lax.scan(step, theta, (bx, by, lrs))
    return theta_out, losses


# ---- whole-network (batched) ops ------------------------------------------
# No vmap here: interpret-mode pallas under vmap/grid-over-batch pays ~1.5 ms
# per grid step on CPU-PJRT, so the whole-network functions are written
# directly on the batch-in-block bmm kernel (EXPERIMENTS.md §Perf, 11x).


def unflatten_all(big_theta, d: int, h: int):
    """Stacked params [N,P] -> (W1 [N,d,h], b1 [N,h], W2 [N,h,1], b2 [N,1])."""
    n = big_theta.shape[0]
    i0 = d * h
    w1 = big_theta[:, :i0].reshape(n, d, h)
    b1 = big_theta[:, i0 : i0 + h]
    w2 = big_theta[:, i0 + h : i0 + 2 * h].reshape(n, h, 1)
    b2 = big_theta[:, i0 + 2 * h :]
    return w1, b1, w2, b2


def logits_all(big_theta, xs, d: int, h: int):
    """Every node's forward pass: [N,P] x [N,B,d] -> [N,B]."""
    w1, b1, w2, b2 = unflatten_all(big_theta, d, h)
    hid = jnp.tanh(bmm(xs, w1) + b1[:, None, :])
    return (bmm(hid, w2) + b2[:, None, :])[..., 0]


def _loss_sum_all(big_theta, xs, ys, d: int, h: int):
    """Sum over nodes of per-node mean losses (aux: per-node losses, logits).

    grad of the *sum* w.r.t. the stacked [N,P] params is exactly the stack of
    per-node gradients — per-node grads without vmap.
    """
    z = logits_all(big_theta, xs, d, h)
    per = jnp.mean(jnp.logaddexp(0.0, z) - ys * z, axis=1)
    return jnp.sum(per), (per, z)


def loss_and_grad_all(big_theta, xs, ys, d: int, h: int):
    """(per-node losses [N], logits [N,B], grads [N,P]) in one fused pass."""
    (_, (per, z)), grads = jax.value_and_grad(
        lambda t: _loss_sum_all(t, xs, ys, d, h), has_aux=True
    )(big_theta)
    return per, z, grads


def local_steps_all(big_theta, bx, by, lrs, d: int, h: int):
    """Whole-network local phase: Q' eq.-4 steps for every node in one call.

    big_theta [N,P], bx [N,Q',m,d], by [N,Q',m], shared lrs [Q'].
    Scans over the step axis with the batched gradient inside.
    """
    bx_t = jnp.swapaxes(bx, 0, 1)  # [Q', N, m, d]
    by_t = jnp.swapaxes(by, 0, 1)  # [Q', N, m]

    def step(t, qb):
        qx, qy, lr = qb
        per, _, g = loss_and_grad_all(t, qx, qy, d, h)
        return t - lr * g, per

    theta_out, losses = lax.scan(step, big_theta, (bx_t, by_t, lrs))
    return theta_out, jnp.swapaxes(losses, 0, 1)  # [N, Q']


def combine(wrow, big_theta):
    """One node's gossip combine (actor mode): sum_j w_j theta_j."""
    return mix_row(wrow, big_theta)


def dsgd_round(w, big_theta, bx, by, lr, d: int, h: int):
    """Paper eq. (2) for all nodes, fused: Theta' = W Theta - lr * G."""
    losses, _, grads = loss_and_grad_all(big_theta, bx, by, d, h)
    theta_next = mix_all(w, big_theta) - lr * grads
    return theta_next, losses


def dsgt_round(w, big_theta, y_tr, g_old, bx, by, lr, d: int, h: int):
    """Paper eq. (3) for all nodes, fused.

    Theta' = W Theta - lr * Y
    Y'     = W Y + grad(Theta') - g_old
    Returns (Theta', Y', grad(Theta'), losses) — the caller threads g as state.
    """
    theta_next = mix_all(w, big_theta) - lr * y_tr
    losses, _, g_new = loss_and_grad_all(theta_next, bx, by, d, h)
    y_next = mix_all(w, y_tr) + g_new - g_old
    return theta_next, y_next, g_new, losses


def _masked_loss_sum_all(big_theta, xs, ys, mask, d: int, h: int):
    """Sum over nodes of per-node *masked-mean* losses (aux: per-node losses,
    logits, row counts).

    ``mask [N,S]`` carries 1.0 for real rows and 0.0 for padded ones, so each
    node's mean runs over exactly its real records; grad of the sum w.r.t.
    the stacked params is the stack of per-node gradients of those exact
    means — the padded rows contribute nothing to loss or gradient.
    """
    counts = jnp.sum(mask, axis=1)
    z = logits_all(big_theta, xs, d, h)
    per = jnp.sum((jnp.logaddexp(0.0, z) - ys * z) * mask, axis=1) / counts
    return jnp.sum(per), (per, z, counts)


def eval_full(big_theta, xs, ys, mask, d: int, h: int):
    """Full-shard metrics: (loss, accuracy, stationarity, consensus).

    ``mask [N,S]`` is 1.0 on real rows, 0.0 on padded ones — the host side
    cycle-pads uneven shards up to the specialized row count and the mask
    makes the reduction exact (no over-weighted prefix rows).

    Loss and accuracy are **record-weighted** over the real rows: each
    node's mean is weighted by its true record count, so both metrics
    describe the same population (the pooled records).  The Theorem-1 terms
    keep their node-mean form:

    stationarity = || (1/N) sum_i grad f_i(theta_i) ||^2   (Theorem 1 LHS, term 1)
    consensus    = (1/N) sum_i || theta_i - theta_bar ||^2 (Theorem 1 LHS, term 2)
    """
    # single fused batched pass: losses, logits and per-node grads together
    # (§Perf L2 optimization — no recomputed forward, no vmap)
    (_, (per, zs, counts)), grads = jax.value_and_grad(
        lambda t: _masked_loss_sum_all(t, xs, ys, mask, d, h), has_aux=True
    )(big_theta)
    total = jnp.sum(counts)
    loss = jnp.sum(per * counts) / total
    correct = ((zs > 0).astype(jnp.float32) == ys).astype(jnp.float32) * mask
    acc = jnp.sum(correct) / total
    mean_grad = jnp.mean(grads, axis=0)
    stat = jnp.sum(mean_grad**2)
    theta_bar = jnp.mean(big_theta, axis=0)
    cons = jnp.mean(jnp.sum((big_theta - theta_bar) ** 2, axis=1))
    return loss, acc, stat, cons
