"""L1 kernel vs pure-jnp oracle: the core correctness signal for the matmul
kernel, including the hypothesis shape sweep mandated for the compile path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import block_shape, matmul, vmem_bytes
from compile.kernels.ref import ref_matmul


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


class TestMatmulBasic:
    def test_square(self):
        rng = np.random.default_rng(0)
        x, w = rand(rng, 16, 16), rand(rng, 16, 16)
        np.testing.assert_allclose(matmul(x, w), ref_matmul(x, w), rtol=1e-5, atol=1e-5)

    def test_paper_shapes_fwd(self):
        # the actual MLP shapes: (m=20, d=42) @ (42, h=32), (20, 32) @ (32, 1)
        rng = np.random.default_rng(1)
        for (a, b, c) in [(20, 42, 32), (20, 32, 1), (500, 42, 32)]:
            x, w = rand(rng, a, b), rand(rng, b, c)
            np.testing.assert_allclose(
                matmul(x, w), ref_matmul(x, w), rtol=1e-5, atol=1e-5
            )

    def test_larger_than_blocks(self):
        # force a multi-tile grid on every axis
        rng = np.random.default_rng(2)
        x, w = rand(rng, 300, 513), rand(rng, 513, 257)
        np.testing.assert_allclose(matmul(x, w), ref_matmul(x, w), rtol=1e-4, atol=1e-4)

    def test_vector_shapes(self):
        rng = np.random.default_rng(3)
        x, w = rand(rng, 1, 7), rand(rng, 7, 1)
        np.testing.assert_allclose(matmul(x, w), ref_matmul(x, w), rtol=1e-5, atol=1e-5)

    def test_contraction_mismatch_raises(self):
        rng = np.random.default_rng(4)
        with pytest.raises(ValueError):
            matmul(rand(rng, 3, 4), rand(rng, 5, 6))

    def test_zero_input(self):
        x = jnp.zeros((9, 11), jnp.float32)
        w = jnp.zeros((11, 5), jnp.float32)
        assert float(jnp.abs(matmul(x, w)).max()) == 0.0

    def test_identity(self):
        rng = np.random.default_rng(5)
        x = rand(rng, 13, 13)
        np.testing.assert_allclose(matmul(x, jnp.eye(13)), x, rtol=1e-6, atol=1e-6)


class TestMatmulGrad:
    def test_vjp_matches_xla_dot(self):
        rng = np.random.default_rng(6)
        x, w = rand(rng, 20, 42), rand(rng, 42, 32)

        def f_pallas(x, w):
            return jnp.sum(jnp.sin(matmul(x, w)))

        def f_ref(x, w):
            return jnp.sum(jnp.sin(ref_matmul(x, w)))

        gx_p, gw_p = jax.grad(f_pallas, argnums=(0, 1))(x, w)
        gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(gw_p, gw_r, rtol=1e-4, atol=1e-5)

    def test_grad_under_vmap(self):
        rng = np.random.default_rng(7)
        xs, ws = rand(rng, 4, 10, 6), rand(rng, 4, 6, 3)

        def f(x, w):
            return jnp.sum(matmul(x, w) ** 2)

        g_p = jax.vmap(jax.grad(f))(xs, ws)
        g_r = jax.vmap(jax.grad(lambda x, w: jnp.sum(ref_matmul(x, w) ** 2)))(xs, ws)
        np.testing.assert_allclose(g_p, g_r, rtol=1e-4, atol=1e-5)


class TestBlockShape:
    def test_small_dims_collapse_grid(self):
        bm, bk, bn = block_shape(20, 42, 32)
        assert bm >= 20 and bk >= 42 and bn >= 32

    def test_quanta(self):
        bm, bk, bn = block_shape(1000, 1000, 1000)
        assert bm % 8 == 0 and bn % 128 == 0 and bk % 128 == 0

    def test_vmem_budget(self):
        # every block set must fit comfortably in 16 MiB VMEM
        for shape in [(20, 42, 32), (500, 42, 32), (4096, 4096, 4096)]:
            assert vmem_bytes(*shape) < 4 * 1024 * 1024


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    np.testing.assert_allclose(matmul(x, w), ref_matmul(x, w), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(100, 300),
    k=st.integers(100, 600),
    n=st.integers(100, 300),
)
def test_matmul_hypothesis_multitile(m, k, n):
    rng = np.random.default_rng(m * 7 + k * 3 + n)
    x = jnp.asarray(rng.standard_normal((m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((k, n)).astype(np.float32))
    np.testing.assert_allclose(matmul(x, w), ref_matmul(x, w), rtol=1e-3, atol=1e-3)
