"""AOT path: golden-input generators and lowering sanity (small shapes so the
test is fast; `make artifacts` does the full-size lowering)."""

import json
import os

import numpy as np

from compile import aot, model


class TestGoldenGenerators:
    def test_golden_vec_deterministic(self):
        a = aot.golden_vec(0, 10, 0.2)
        b = aot.golden_vec(0, 10, 0.2)
        np.testing.assert_array_equal(a, b)

    def test_golden_vec_offset_disjoint(self):
        a = aot.golden_vec(0, 10, 1.0)
        b = aot.golden_vec(10, 10, 1.0)
        assert not np.allclose(a, b)
        # offset slices must agree with one long draw
        long = aot.golden_vec(0, 20, 1.0)
        np.testing.assert_array_equal(long[10:], b)

    def test_golden_vec_range(self):
        v = aot.golden_vec(0, 1000, 2.0)
        assert v.dtype == np.float32
        assert float(v.min()) >= -1.0 and float(v.max()) <= 1.0

    def test_golden_vec_known_value(self):
        # hand-computed: hash(1) = 2654435761 mod 2^32 = 2654435761
        # v = (2654435761 / 2^32 - 0.5) * 1.0
        expected = np.float32((2654435761 / 2.0**32 - 0.5) * 1.0)
        assert aot.golden_vec(0, 1, 1.0)[0] == expected

    def test_golden_labels_binary(self):
        y = aot.golden_labels(0, 100)
        assert set(np.unique(y)).issubset({0.0, 1.0})
        # both classes present
        assert 0.0 in y and 1.0 in y


class TestLowering:
    def test_lower_all_artifacts_small(self):
        n, d, h, m, q, shard = 4, 6, 5, 3, 2, 7
        arts, p = aot.lower_artifacts(n, d, h, m, q, shard)
        assert p == model.param_count(d, h)
        assert set(arts) == {
            "grad_step", "local_steps", "local_steps_all", "combine",
            "dsgd_round", "dsgt_round", "eval_full", "predict",
        }
        for name, (lowered, ins, outs) in arts.items():
            text = aot.to_hlo_text(lowered)
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_goldens_finite(self):
        g = aot.compute_goldens(n=4, d=6, h=5, m=3, q=2, p=model.param_count(6, 5))
        for section in g.values():
            for v in section.values():
                arr = np.asarray(v)
                assert np.all(np.isfinite(arr))

    def test_manifest_end_to_end(self, tmp_path):
        import subprocess, sys
        env = dict(os.environ)
        pydir = os.path.join(os.path.dirname(__file__), "..")
        out = subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", str(tmp_path),
             "--n", "3", "--d", "4", "--hidden", "3", "--m", "2", "--q", "2", "--shard", "5"],
            cwd=pydir, env=env, capture_output=True, text=True, timeout=600,
        )
        assert out.returncode == 0, out.stderr
        man = json.loads((tmp_path / "manifest.json").read_text())
        assert man["config"]["p"] == model.param_count(4, 3)
        for art in man["artifacts"].values():
            assert (tmp_path / art["file"]).exists()
