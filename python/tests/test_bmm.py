"""Batched (batch-in-block) matmul kernel vs oracle + VMEM budget checks."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.bmm import bmm, block_shape_batched, vmem_bytes_batched
from compile.kernels.ref import ref_bmm


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32))


class TestBmm:
    def test_paper_shapes(self):
        rng = np.random.default_rng(0)
        # layer 1 and layer 2 of the whole-network forward
        for (b, m, k, n) in [(20, 20, 42, 32), (20, 20, 32, 1), (20, 500, 42, 32)]:
            x, w = rand(rng, b, m, k), rand(rng, b, k, n)
            np.testing.assert_allclose(bmm(x, w), ref_bmm(x, w), rtol=1e-4, atol=1e-4)

    def test_batch_larger_than_block(self):
        rng = np.random.default_rng(1)
        x, w = rand(rng, 50, 9, 17), rand(rng, 50, 17, 5)
        np.testing.assert_allclose(bmm(x, w), ref_bmm(x, w), rtol=1e-4, atol=1e-4)

    def test_multi_tile_contraction(self):
        rng = np.random.default_rng(2)
        x, w = rand(rng, 3, 40, 600), rand(rng, 3, 600, 40)
        np.testing.assert_allclose(bmm(x, w), ref_bmm(x, w), rtol=1e-3, atol=1e-3)

    def test_grad_matches_einsum(self):
        rng = np.random.default_rng(3)
        x, w = rand(rng, 4, 10, 6), rand(rng, 4, 6, 3)
        g_p = jax.grad(lambda a, b: jnp.sum(jnp.sin(bmm(a, b))), argnums=(0, 1))(x, w)
        g_r = jax.grad(lambda a, b: jnp.sum(jnp.sin(ref_bmm(a, b))), argnums=(0, 1))(x, w)
        np.testing.assert_allclose(g_p[0], g_r[0], rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(g_p[1], g_r[1], rtol=1e-4, atol=1e-5)

    def test_shape_mismatch_raises(self):
        rng = np.random.default_rng(4)
        try:
            bmm(rand(rng, 2, 3, 4), rand(rng, 3, 4, 5))
            raise AssertionError("expected ValueError")
        except ValueError:
            pass

    def test_vmem_budget_paper_shapes(self):
        # the whole-network round must stay far below 16 MiB VMEM per step
        assert vmem_bytes_batched(20, 20, 42, 32) < 8 * 1024 * 1024
        assert vmem_bytes_batched(20, 500, 42, 32) < 8 * 1024 * 1024
        bb, bm, bk, bn = block_shape_batched(20, 20, 42, 32)
        assert bb >= 20, "paper batch must fit one block (single grid step)"


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 40),
    m=st.integers(1, 40),
    k=st.integers(1, 50),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
)
def test_bmm_hypothesis(b, m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, m, k)).astype(np.float32))
    w = jnp.asarray(rng.standard_normal((b, k, n)).astype(np.float32))
    np.testing.assert_allclose(bmm(x, w), ref_bmm(x, w), rtol=1e-4, atol=1e-4)
