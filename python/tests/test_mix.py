"""Gossip-mixing kernel vs oracle, plus the doubly-stochastic invariants the
decentralized algorithms rely on (Assumption 1 consequences)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.mix import mix_all, mix_row
from compile.kernels.ref import ref_mix_all, ref_mix_row


def metropolis(adj: np.ndarray) -> np.ndarray:
    """Reference Metropolis-Hastings weights for a 0/1 adjacency matrix."""
    n = adj.shape[0]
    deg = adj.sum(axis=1)
    w = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        for j in range(n):
            if i != j and adj[i, j]:
                w[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        w[i, i] = 1.0 - w[i].sum()
    return w


def ring_adj(n: int) -> np.ndarray:
    a = np.zeros((n, n), dtype=np.float32)
    for i in range(n):
        a[i, (i + 1) % n] = a[(i + 1) % n, i] = 1.0
    return a


class TestMixAll:
    def test_matches_ref(self):
        rng = np.random.default_rng(0)
        w = jnp.asarray(metropolis(ring_adj(20)))
        theta = jnp.asarray(rng.standard_normal((20, 1409)).astype(np.float32))
        np.testing.assert_allclose(mix_all(w, theta), ref_mix_all(w, theta), rtol=1e-5, atol=1e-5)

    def test_identity_weights_fixed_point(self):
        rng = np.random.default_rng(1)
        theta = jnp.asarray(rng.standard_normal((8, 100)).astype(np.float32))
        np.testing.assert_allclose(mix_all(jnp.eye(8), theta), theta, rtol=1e-6, atol=1e-6)

    def test_preserves_consensus(self):
        # if all nodes agree, mixing is a no-op (W 1 = 1)
        w = jnp.asarray(metropolis(ring_adj(10)))
        theta = jnp.tile(jnp.arange(50, dtype=jnp.float32)[None, :], (10, 1))
        np.testing.assert_allclose(mix_all(w, theta), theta, rtol=1e-5, atol=1e-5)

    def test_preserves_mean(self):
        # column-stochastic W preserves the network average (key DSGT invariant)
        rng = np.random.default_rng(2)
        w = jnp.asarray(metropolis(ring_adj(12)))
        theta = jnp.asarray(rng.standard_normal((12, 64)).astype(np.float32))
        np.testing.assert_allclose(
            jnp.mean(mix_all(w, theta), axis=0), jnp.mean(theta, axis=0), rtol=1e-4, atol=1e-5
        )

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError):
            mix_all(jnp.eye(4), jnp.zeros((5, 10)))


class TestMixRow:
    def test_matches_ref(self):
        rng = np.random.default_rng(3)
        wrow = jnp.asarray(metropolis(ring_adj(20))[0])
        theta = jnp.asarray(rng.standard_normal((20, 1409)).astype(np.float32))
        np.testing.assert_allclose(mix_row(wrow, theta), ref_mix_row(wrow, theta), rtol=1e-5, atol=1e-5)

    def test_one_hot_selects_row(self):
        rng = np.random.default_rng(4)
        theta = jnp.asarray(rng.standard_normal((6, 33)).astype(np.float32))
        onehot = jnp.zeros(6).at[3].set(1.0)
        np.testing.assert_allclose(mix_row(onehot, theta), theta[3], rtol=1e-6, atol=1e-6)

    def test_consistent_with_mix_all(self):
        rng = np.random.default_rng(5)
        w = jnp.asarray(metropolis(ring_adj(9)))
        theta = jnp.asarray(rng.standard_normal((9, 200)).astype(np.float32))
        full = mix_all(w, theta)
        for i in range(9):
            np.testing.assert_allclose(mix_row(w[i], theta), full[i], rtol=1e-5, atol=1e-5)

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError):
            mix_row(jnp.zeros(4), jnp.zeros((5, 10)))


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 40), p=st.integers(1, 700), seed=st.integers(0, 2**31 - 1))
def test_mix_hypothesis(n, p, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    theta = jnp.asarray(rng.standard_normal((n, p)).astype(np.float32))
    np.testing.assert_allclose(mix_all(w, theta), ref_mix_all(w, theta), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(mix_row(w[0], theta), ref_mix_row(w[0], theta), rtol=1e-4, atol=1e-4)
