"""L2 model vs pure-jnp oracle: every update rule the rust coordinator will
execute through the AOT artifacts, checked against ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

D, H = 42, 32
P = model.param_count(D, H)


def make(rng, *shape, scale=1.0):
    return jnp.asarray((rng.standard_normal(shape) * scale).astype(np.float32))


def labels(rng, *shape):
    return jnp.asarray(rng.integers(0, 2, shape).astype(np.float32))


class TestForward:
    def test_param_count(self):
        assert P == 42 * 32 + 32 + 32 + 1 == 1409

    def test_unflatten_roundtrip(self):
        rng = np.random.default_rng(0)
        theta = make(rng, P)
        w1, b1, w2, b2 = model.unflatten(theta, D, H)
        assert w1.shape == (D, H) and b1.shape == (H,)
        assert w2.shape == (H, 1) and b2.shape == (1,)
        flat = jnp.concatenate([w1.ravel(), b1, w2.ravel(), b2])
        np.testing.assert_array_equal(flat, theta)

    def test_logits_match_ref(self):
        rng = np.random.default_rng(1)
        theta, x = make(rng, P, scale=0.2), make(rng, 20, D)
        np.testing.assert_allclose(
            model.logits(theta, x, D, H), ref.ref_logits(theta, x, D, H), rtol=1e-4, atol=1e-5
        )

    def test_loss_matches_ref(self):
        rng = np.random.default_rng(2)
        theta, x, y = make(rng, P, scale=0.2), make(rng, 20, D), labels(rng, 20)
        np.testing.assert_allclose(
            model.loss(theta, x, y, D, H), ref.ref_loss(theta, x, y, D, H), rtol=1e-5, atol=1e-6
        )

    def test_predict_is_sigmoid_of_logits(self):
        rng = np.random.default_rng(3)
        theta, x = make(rng, P, scale=0.2), make(rng, 10, D)
        pr = model.predict(theta, x, D, H)
        assert float(pr.min()) >= 0.0 and float(pr.max()) <= 1.0
        np.testing.assert_allclose(
            pr, jax.nn.sigmoid(ref.ref_logits(theta, x, D, H)), rtol=1e-4, atol=1e-5
        )

    def test_loss_at_zero_params_is_log2(self):
        rng = np.random.default_rng(4)
        x, y = make(rng, 30, D), labels(rng, 30)
        np.testing.assert_allclose(
            model.loss(jnp.zeros(P), x, y, D, H), np.log(2.0), rtol=1e-5
        )


class TestGrad:
    def test_grad_matches_ref(self):
        rng = np.random.default_rng(5)
        theta, x, y = make(rng, P, scale=0.2), make(rng, 20, D), labels(rng, 20)
        l_p, g_p = model.loss_and_grad(theta, x, y, D, H)
        l_r, g_r = ref.ref_loss_and_grad(theta, x, y, D, H)
        np.testing.assert_allclose(l_p, l_r, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(g_p, g_r, rtol=1e-4, atol=1e-5)

    def test_grad_matches_finite_differences(self):
        rng = np.random.default_rng(6)
        theta, x, y = make(rng, P, scale=0.1), make(rng, 10, D), labels(rng, 10)
        _, g = model.loss_and_grad(theta, x, y, D, H)
        eps = 1e-3
        for idx in [0, P // 2, P - 1]:
            e = jnp.zeros(P).at[idx].set(eps)
            fd = (model.loss(theta + e, x, y, D, H) - model.loss(theta - e, x, y, D, H)) / (2 * eps)
            np.testing.assert_allclose(g[idx], fd, rtol=2e-2, atol=2e-4)

    def test_gradient_descent_decreases_loss(self):
        rng = np.random.default_rng(7)
        theta, x, y = make(rng, P, scale=0.1), make(rng, 50, D), labels(rng, 50)
        l0, g = model.loss_and_grad(theta, x, y, D, H)
        l1 = model.loss(theta - 0.1 * g, x, y, D, H)
        assert float(l1) < float(l0)


class TestLocalSteps:
    def test_matches_ref_unrolled(self):
        rng = np.random.default_rng(8)
        q, m = 5, 10
        theta = make(rng, P, scale=0.2)
        bx, by = make(rng, q, m, D), labels(rng, q, m)
        lrs = jnp.asarray((0.02 / np.sqrt(np.arange(1, q + 1))).astype(np.float32))
        t_p, l_p = model.local_steps(theta, bx, by, lrs, D, H)
        t_r, l_r = ref.ref_local_steps(theta, bx, by, lrs, D, H)
        np.testing.assert_allclose(t_p, t_r, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(l_p, l_r, rtol=1e-4, atol=1e-5)

    def test_q1_equals_single_grad_step(self):
        rng = np.random.default_rng(9)
        theta = make(rng, P, scale=0.2)
        x, y = make(rng, 1, 20, D), labels(rng, 1, 20)
        lr = jnp.asarray([0.05], dtype=jnp.float32)
        t_scan, _ = model.local_steps(theta, x, y, lr, D, H)
        _, g = model.loss_and_grad(theta, x[0], y[0], D, H)
        np.testing.assert_allclose(t_scan, theta - 0.05 * g, rtol=1e-5, atol=1e-6)


class TestRounds:
    def setup_method(self):
        self.rng = np.random.default_rng(10)
        self.n, self.m = 6, 8
        adj = np.zeros((self.n, self.n), dtype=np.float32)
        for i in range(self.n):
            adj[i, (i + 1) % self.n] = adj[(i + 1) % self.n, i] = 1.0
        deg = adj.sum(1)
        w = np.zeros_like(adj)
        for i in range(self.n):
            for j in range(self.n):
                if i != j and adj[i, j]:
                    w[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
            w[i, i] = 1.0 - w[i].sum()
        self.w = jnp.asarray(w)
        self.theta = make(self.rng, self.n, P, scale=0.2)
        self.bx = make(self.rng, self.n, self.m, D)
        self.by = labels(self.rng, self.n, self.m)

    def test_dsgd_round_matches_ref(self):
        t_p, l_p = model.dsgd_round(self.w, self.theta, self.bx, self.by, 0.05, D, H)
        t_r, l_r = ref.ref_dsgd_round(self.w, self.theta, self.bx, self.by, 0.05, D, H)
        np.testing.assert_allclose(t_p, t_r, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(l_p, l_r, rtol=1e-4, atol=1e-5)

    def test_dsgt_round_matches_ref(self):
        y0 = make(self.rng, self.n, P, scale=0.1)
        g0 = make(self.rng, self.n, P, scale=0.1)
        out_p = model.dsgt_round(self.w, self.theta, y0, g0, self.bx, self.by, 0.05, D, H)
        out_r = ref.ref_dsgt_round(self.w, self.theta, y0, g0, self.bx, self.by, 0.05, D, H)
        for a, b in zip(out_p, out_r):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    def test_dsgt_preserves_tracker_mean(self):
        # key GT invariant: mean(Y') = mean(Y) + mean(G_new) - mean(G_old)
        _, g0 = jax.vmap(lambda t, x_, y_: model.loss_and_grad(t, x_, y_, D, H))(
            self.theta, self.bx, self.by
        )
        y0 = g0
        t1, y1, g1, _ = model.dsgt_round(self.w, self.theta, y0, g0, self.bx, self.by, 0.05, D, H)
        np.testing.assert_allclose(
            jnp.mean(y1, axis=0), jnp.mean(g1, axis=0), rtol=1e-3, atol=1e-5
        )

    def test_eval_full_matches_ref(self):
        ones = jnp.ones((self.n, self.m), dtype=jnp.float32)
        out_p = model.eval_full(self.theta, self.bx, self.by, ones, D, H)
        out_r = ref.ref_eval_full(self.theta, self.bx, self.by, np.asarray(ones), D, H)
        for a, b in zip(out_p, out_r):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    def test_eval_consensus_zero_at_consensus(self):
        same = jnp.tile(self.theta[0][None, :], (self.n, 1))
        ones = jnp.ones((self.n, self.m), dtype=jnp.float32)
        _, _, _, cons = model.eval_full(same, self.bx, self.by, ones, D, H)
        assert float(cons) < 1e-8

    def test_eval_full_mask_makes_cycle_padding_exact(self):
        # shard of k real rows cycle-padded to m (the rust host-side layout):
        # the masked eval must equal the eval of the exact k-row shards —
        # the old unmasked artifact over-weighted the first m % k rows
        k = 5  # real rows per node; padded up to self.m = 8
        bx = np.asarray(self.bx).copy()
        by = np.asarray(self.by).copy()
        mask = np.zeros((self.n, self.m), dtype=np.float32)
        for i in range(self.n):
            for s in range(self.m):
                bx[i, s] = bx[i, s % k]
                by[i, s] = by[i, s % k]
            mask[i, :k] = 1.0
        out_masked = model.eval_full(
            self.theta, jnp.asarray(bx), jnp.asarray(by), jnp.asarray(mask), D, H
        )
        exact_ones = jnp.ones((self.n, k), dtype=jnp.float32)
        out_exact = model.eval_full(
            self.theta,
            jnp.asarray(bx[:, :k]),
            jnp.asarray(by[:, :k]),
            exact_ones,
            D,
            H,
        )
        for a, b in zip(out_masked, out_exact):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)
        # and the padded rows would have biased the unmasked mean
        full_ones = jnp.ones((self.n, self.m), dtype=jnp.float32)
        biased = model.eval_full(
            self.theta, jnp.asarray(bx), jnp.asarray(by), full_ones, D, H
        )
        assert abs(float(biased[0]) - float(out_exact[0])) > 1e-7

    def test_eval_full_loss_is_record_weighted(self):
        # nodes with different real-row counts: global loss must be the
        # record mean sum(n_i * loss_i) / sum(n_i), not the node mean
        counts = [2, 8, 5, 8, 3, 8]
        mask = np.zeros((self.n, self.m), dtype=np.float32)
        for i, k in enumerate(counts):
            mask[i, :k] = 1.0
        loss, acc, _, _ = model.eval_full(
            self.theta, self.bx, self.by, jnp.asarray(mask), D, H
        )
        per, corr = [], 0.0
        for i, k in enumerate(counts):
            per.append(float(ref.ref_loss(self.theta[i], self.bx[i, :k], self.by[i, :k], D, H)))
            z = ref.ref_logits(self.theta[i], self.bx[i, :k], D, H)
            corr += float(
                jnp.sum(((z > 0).astype(jnp.float32) == self.by[i, :k]).astype(jnp.float32))
            )
        total = float(sum(counts))
        expect = sum(p * k for p, k in zip(per, counts)) / total
        np.testing.assert_allclose(float(loss), expect, rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(float(acc), corr / total, rtol=1e-6)
        node_mean = sum(per) / self.n
        assert abs(float(loss) - node_mean) > 1e-7, "weighting must differ from node mean"


@settings(max_examples=15, deadline=None)
@given(
    d=st.integers(2, 50),
    h=st.integers(1, 40),
    m=st.integers(1, 30),
    seed=st.integers(0, 2**31 - 1),
)
def test_model_grad_hypothesis(d, h, m, seed):
    rng = np.random.default_rng(seed)
    p = model.param_count(d, h)
    theta = jnp.asarray((rng.standard_normal(p) * 0.2).astype(np.float32))
    x = jnp.asarray(rng.standard_normal((m, d)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 2, m).astype(np.float32))
    l_p, g_p = model.loss_and_grad(theta, x, y, d, h)
    l_r, g_r = ref.ref_loss_and_grad(theta, x, y, d, h)
    np.testing.assert_allclose(l_p, l_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(g_p, g_r, rtol=1e-3, atol=1e-4)
