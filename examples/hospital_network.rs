//! Fig. 1 end to end: build the 20-hospital network, generate the synthetic
//! EHR cohort, and reproduce both panels — the graph (left) and the t-SNE of
//! three hospitals (right) — writing plot-ready JSON + DOT to out/.
//!
//!     cargo run --release --example hospital_network

use decfl::config::ExperimentConfig;
use decfl::data::{generate, DataConfig};
use decfl::experiments::fig1;

fn main() -> anyhow::Result<()> {
    let cfg = ExperimentConfig::default();
    std::fs::create_dir_all("out")?;

    // ---- left panel: the hospital graph ----
    let graph = fig1::hospital_graph(&cfg)?;
    graph.print_summary();
    std::fs::write("out/fig1_graph.dot", &graph.dot)?;
    std::fs::write("out/fig1_graph.json", graph.to_json().to_string())?;
    println!("  -> out/fig1_graph.dot, out/fig1_graph.json");

    // ---- the cohort itself ----
    let ds = generate(&DataConfig {
        n_hospitals: cfg.n,
        records_per_hospital: cfg.records_per_hospital,
        records_jitter: 50,
        heterogeneity: cfg.heterogeneity,
        ..DataConfig::default()
    })?;
    println!(
        "\ncohort: {} hospitals, {} train + {} test records, AD prevalence {:.3} \
         (paper: 2103/10022 = 0.210)",
        ds.n_hospitals(),
        ds.total_records(),
        ds.test.n,
        ds.global_prevalence()
    );
    println!(
        "per-hospital prevalence range: {:.3} .. {:.3}  |  site divergence {:.3}",
        ds.prevalences.iter().cloned().fold(f64::INFINITY, f64::min),
        ds.prevalences.iter().cloned().fold(0.0, f64::max),
        ds.site_divergence()
    );

    // ---- right panel: t-SNE of three hospitals ----
    let tsne = fig1::tsne_hospitals(&cfg, &[0, 1, 2], 150, 30.0)?;
    tsne.print_summary();
    std::fs::write("out/fig1_tsne.json", tsne.to_json().to_string())?;
    println!("  -> out/fig1_tsne.json");

    // contrast: the same three hospitals under iid sharding
    let mut iid = cfg.clone();
    iid.heterogeneity = 0.0;
    let tsne_iid = fig1::tsne_hospitals(&iid, &[0, 1, 2], 150, 30.0)?;
    println!(
        "control (iid shards): silhouette {:.3} — heterogeneity is what separates \
         the clusters, exactly the paper's Fig. 1R argument",
        tsne_iid.silhouette
    );
    Ok(())
}
