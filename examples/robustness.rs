//! Robustness: actor-mode training over a degraded hospital WAN.
//!
//! Runs the same FD-DSGT workload over (a) a clean 100 Mbit/s network and
//! (b) a lossy, slow one (20% frame loss, 10 Mbit/s, 50 ms latency), using
//! the per-node thread + message-channel runtime.  Shows that
//! — the trajectory is *identical* (synchronous gossip retransmits losses),
//! — the communication bill is not: retransmitted bytes and simulated time
//!   grow, which is exactly what the Q-local-steps design amortizes.
//!
//!     cargo run --release --example robustness

use decfl::config::{AlgoKind, Backend, ExperimentConfig, Mode};
use decfl::coordinator::{assemble, run_on};

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.algo = AlgoKind::FdDsgt;
    cfg.mode = Mode::Actors;
    cfg.n = 10;
    cfg.hidden = 16;
    cfg.q = 20;
    cfg.total_steps = 600; // 30 comm rounds
    cfg.eval_every = 5;
    cfg.records_per_hospital = 200;
    cfg.backend = Backend::Native; // shape-free; PJRT path covered by fed_training

    println!("actor-mode FD-DSGT, {} hospitals, Q={}, {} comm rounds\n", cfg.n, cfg.q, 30);

    let mut results = Vec::new();
    for (label, latency, bw, drop) in [
        ("clean WAN (100 Mbit/s, 10 ms)", 0.010, 12_500_000.0, 0.0),
        ("degraded WAN (10 Mbit/s, 50 ms, 20% loss)", 0.050, 1_250_000.0, 0.20),
    ] {
        let mut c = cfg.clone();
        c.latency_s = latency;
        c.bandwidth_bps = bw;
        c.drop_prob = drop;
        let asm = assemble(&c)?;
        let log = run_on(&c, &asm)?;
        let last = log.last().unwrap();
        println!(
            "{label}\n  final loss {:.4}  consensus {:.2e}  bytes {:.2} MB  sim time {:.1}s  msgs {}",
            last.loss,
            last.consensus,
            last.bytes as f64 / 1e6,
            last.sim_time_s,
            last.messages
        );
        results.push((label, last.loss, last.bytes, last.sim_time_s));
    }

    let (l0, b0, t0) = (results[0].1, results[0].2, results[0].3);
    let (l1, b1, t1) = (results[1].1, results[1].2, results[1].3);
    println!("\ntrajectory identical: {}", if (l0 - l1).abs() < 1e-9 { "YES (loss matches bit-for-bit)" } else { "no" });
    println!(
        "cost of degradation: {:.2}x bytes (retransmission), {:.1}x simulated time",
        b1 as f64 / b0 as f64,
        t1 / t0
    );
    Ok(())
}
