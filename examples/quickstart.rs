//! Quickstart: train FD-DSGT on a small synthetic hospital network and print
//! the convergence table.
//!
//!     make artifacts            # once (AOT-compiles the jax/pallas model)
//!     cargo run --release --example quickstart
//!
//! Uses the PJRT artifacts when present, otherwise falls back to the native
//! backend so the example always runs.

use decfl::config::{AlgoKind, Backend, ExperimentConfig};
use decfl::coordinator::{assemble, run_on};

fn main() -> anyhow::Result<()> {
    let mut cfg = ExperimentConfig::default();
    cfg.algo = AlgoKind::FdDsgt;

    // small budget so the quickstart finishes in seconds
    cfg.total_steps = 2_000; // 20 comm rounds at Q=100
    cfg.eval_every = 2;

    if !std::path::Path::new(&cfg.artifacts_dir).join("manifest.json").exists() {
        eprintln!("artifacts/ missing — using the native backend (run `make artifacts` for PJRT)");
        cfg.backend = Backend::Native;
    }

    println!(
        "federated cohort: {} hospitals x ~{} records, heterogeneity {}",
        cfg.n, cfg.records_per_hospital, cfg.heterogeneity
    );
    let asm = assemble(&cfg)?;
    println!(
        "hospital graph: {} edges, diameter {}, spectral gap {:.4}",
        asm.graph.edge_count(),
        asm.graph.diameter(),
        asm.spectral_gap
    );

    let log = run_on(&cfg, &asm)?;
    println!("\n{:>6} {:>10} {:>8} {:>13} {:>13} {:>9}", "round", "loss", "acc", "stationarity", "consensus", "MB sent");
    for r in &log.rows {
        println!(
            "{:>6} {:>10.4} {:>8.3} {:>13.3e} {:>13.3e} {:>9.2}",
            r.comm_rounds,
            r.loss,
            r.accuracy,
            r.stationarity,
            r.consensus,
            r.bytes as f64 / 1e6
        );
    }
    let last = log.last().unwrap();
    println!(
        "\ntrained {} local steps in {} communication rounds — every hospital now \
         holds a consensus model (consensus error {:.2e}) without any patient record \
         leaving its site.",
        last.local_steps, last.comm_rounds, last.consensus
    );
    Ok(())
}
