//! END-TO-END DRIVER (EXPERIMENTS.md §E2E): the paper's full workload
//! through all three layers.
//!
//! 20 hospitals × ~500 EHR records, shallow NN (d=42), FD-DSGT with m=20,
//! Q=100, α_r = 0.02/√r — trained for `--steps` local iterations (default
//! 10,000 = 100 communication rounds) through the AOT-compiled PJRT
//! artifacts, then evaluated on the held-out test set (accuracy + AUC).
//!
//!     make artifacts
//!     cargo run --release --example fed_training -- [--steps N] [--algo a] [--mode actors]
//!
//! Writes the loss curve to out/fed_training_<algo>.json.

use decfl::cli::{apply_common_overrides, Args};
use decfl::config::{ExperimentConfig, Mode};
use decfl::coordinator::{assemble, baselines::auc, fused, make_compute, run_on};

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let mut cfg = ExperimentConfig::default();
    apply_common_overrides(&args, &mut cfg)?;
    args.finish()?;
    cfg.validate()?;
    if cfg.eval_every == 1 && cfg.total_steps >= 5_000 {
        cfg.eval_every = 5; // keep the log readable on the full run
    }

    println!(
        "E2E: {} | backend {:?} mode {:?} | N={} d={} hidden={} m={} Q={} T={} α0={}",
        cfg.algo.name(), cfg.backend, cfg.mode, cfg.n, cfg.d, cfg.hidden,
        cfg.m, cfg.algo.effective_q(cfg.q), cfg.total_steps, cfg.alpha0
    );

    let asm = assemble(&cfg)?;
    println!(
        "cohort {} records ({} test), prevalence {:.3}; graph {} edges, spectral gap {:.4}",
        asm.ds.total_records(),
        asm.ds.test.n,
        asm.ds.global_prevalence(),
        asm.graph.edge_count(),
        asm.spectral_gap
    );

    let wall = std::time::Instant::now();
    let log = run_on(&cfg, &asm)?;
    let train_secs = wall.elapsed().as_secs_f64();

    println!("\nloss curve (comm round → loss / stationarity / consensus):");
    let k = 12.min(log.rows.len());
    for i in 0..k {
        let r = &log.rows[i * (log.rows.len() - 1) / (k - 1).max(1)];
        println!(
            "  {:>6}  {:.4}  {:.3e}  {:.3e}",
            r.comm_rounds, r.loss, r.stationarity, r.consensus
        );
    }
    let last = log.last().unwrap();
    println!(
        "\nfinal: train loss {:.4}, train acc {:.3}, stationarity {:.3e}, consensus {:.3e}",
        last.loss, last.accuracy, last.stationarity, last.consensus
    );
    println!(
        "comm cost: {} rounds, {} messages, {:.1} MB, sim time {:.1}s | wall {:.1}s",
        last.comm_rounds, last.messages, last.bytes as f64 / 1e6, last.sim_time_s, train_secs
    );

    // ---- held-out evaluation with the consensus model (node 0's params) ----
    if matches!(cfg.mode, Mode::Fused) && !matches!(cfg.algo, decfl::config::AlgoKind::Centralized | decfl::config::AlgoKind::FedAvg) {
        let compute = make_compute(&cfg)?;
        let (_, theta) = fused::train_returning_params(&cfg, compute.as_ref(), &asm.ds, &asm.graph, &asm.w)?;
        let p = compute.dims().2;
        let node0 = &theta[..p];
        let probs = compute.predict(node0, &asm.ds.test.x)?;
        let acc = probs
            .iter()
            .zip(&asm.ds.test.y)
            .filter(|(pr, &y)| ((**pr > 0.5) as u32 as f32) == y)
            .count() as f64
            / asm.ds.test.n as f64;
        let test_auc = auc(compute.as_ref(), node0, &asm.ds.test)?;
        println!("held-out: accuracy {acc:.3}, AUC {test_auc:.3} (node-0 consensus model)");
    }

    std::fs::create_dir_all("out")?;
    let path = format!("out/fed_training_{}.json", cfg.algo.name());
    std::fs::write(&path, log.to_json().to_string())?;
    println!("wrote {path}");
    Ok(())
}
